//! # hsm — Hierarchical Shift Mixing
//!
//! A production-style reproduction of *"Hierarchical Shift Mixing — Beyond
//! Dense Attention in Transformers"* (Forchheimer, 2026).
//!
//! HSM replaces the dense softmax-attention mixer of a GPT-style decoder
//! with pairwise token mixing at layer-doubling temporal shifts, giving
//! linear-time complexity while covering multi-scale token interactions
//! across the layer stack.  This crate is **layer 3** of a three-layer
//! stack:
//!
//! * **L1** — Pallas kernels (shift-mix, causal flash attention, gated
//!   combine) authored in `python/compile/kernels/`.
//! * **L2** — the JAX decoder with all twelve mixer variants in
//!   `python/compile/model.py`, AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: tokenizer, corpus, data pipeline, the PJRT
//!   runtime that executes the artifacts (feature `pjrt`, on by
//!   default), the training coordinator, the native serving stack, and
//!   the experiment/report drivers that regenerate every table and
//!   figure of the paper.
//!
//! Python never runs on the training or inference path: `make artifacts`
//! lowers the model once, and the `hsm` binary is self-contained
//! afterwards.
//!
//! ## Module map
//!
//! | module        | role                                                        |
//! |---------------|-------------------------------------------------------------|
//! | [`config`]    | manifests, presets, variant registry, synthetic manifests   |
//! | [`tokenizer`] | byte-level BPE (train / encode / decode / (de)serialize)    |
//! | [`corpus`]    | TinyStories-like synthetic corpus                           |
//! | [`data`]      | window datasets + epoch shuffling                           |
//! | [`runtime`]   | [`StepEngine`] trait; `PjrtEngine` behind feature `pjrt`    |
//! | [`coordinator`] | training loops, `MockEngine`, experiment scheduler        |
//! | [`infer`]     | [`infer::Decoder`] trait, shared-weight [`infer::Model`], per-user [`infer::DecodeSession`]s with forkable [`infer::SessionState`] snapshots, [`infer::NativeDecoder`], full-context [`infer::WindowEngine`] |
//! | [`generation`] | sampling + [`generation::generate`] / [`generation::generate_batch`] over any [`infer::Decoder`]; [`generation::WindowDecoder`] |
//! | [`serve`]     | **serving**: continuous-batching [`serve::Scheduler`] — [`serve::Request`]→[`serve::Completion`] lifecycle, admission control (`max_active`, `max_queue_wait`), worker threads over disjoint sessions; shared [`serve::PrefixCache`] of prompt-head snapshots; byte-exact speculative decoding ([`serve::ServeCfg::speculation`], drafters in [`infer::speculate`]); resident [`serve::StreamScheduler`] emitting per-token [`serve::TokenEvent`]s, cancel-on-disconnect |
//! | [`server`]    | **cross-process serving**: hand-rolled HTTP/1.1 front-end — `POST /v1/generate`, `POST /v1/stream` (SSE chunks), `GET /healthz`, `GET /metrics`, blocking [`server::client`] |
//! | [`loadgen`]   | **open-loop load harness**: seeded Poisson arrivals + Zipf prompt reuse, `/metrics` differencing for TTFT/queue-wait quantiles, `BENCH_load.json` |
//! | [`obs`]       | **telemetry**: lock-free [`obs::MetricsRegistry`] (latency histograms, request/cache/spec counters, per-stage step timing), Prometheus text exposition, JSON-lines [`obs::RequestLog`] |
//! | [`checkpoint`] | tensor (de)serialization (+ embedded manifest snapshot)    |
//! | [`report`]    | Table 1/2/3, Figures 7/8 drivers                            |
//! | [`report_sinks`] | csv/markdown/stats helpers for the report drivers        |
//!
//! ## Generation = prefill + step
//!
//! All generation drives the [`infer::Decoder`] trait: `prefill` the
//! prompt (no logit projection needed), then one `step` per sampled
//! token.  The native implementation keeps **O(1) state per HSM layer**
//! (a ring buffer at the layer's shift) so per-token cost is flat in
//! position — the paper's linearity claim, turned into the serving
//! architecture.  Weights live in an `Arc`-shared [`infer::Model`];
//! every concurrent user costs only a [`infer::DecodeSession`] (rings +
//! scratch), and [`generation::generate_batch`] round-robins any number
//! of sessions over one weight set.
//!
//! ## Quick start: serving (no artifacts needed)
//!
//! ```no_run
//! use hsm::config::{LayerInfo, Manifest};
//! use hsm::generation::SampleCfg;
//! use hsm::infer::{weights, Model, ModelWeights};
//! use hsm::serve::{Request, Scheduler, ServeCfg};
//! use hsm::tokenizer::trainer as bpe;
//!
//! # fn main() -> anyhow::Result<()> {
//! // A two-layer HSM (a,b) model with doubling shifts, built in memory.
//! let layers = vec![
//!     LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 64 },
//!     LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 64 },
//! ];
//! let text = hsm::corpus::generate(1234, 500);
//! let tok = bpe::train(&text, 300)?;
//! let m = Manifest::synthetic("hsm_ab", layers, 32, 128, tok.vocab_size(), 1);
//! let flat = weights::seeded_flat(&m, 42);
//! let model = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat)?)?;
//!
//! // Continuous batching: at most 4 concurrent sessions over one weight
//! // set, 4 worker threads; a finishing request immediately admits the
//! // next one.  Request ids (not scheduling order) fix the sampled text.
//! let sched = Scheduler::new(model, ServeCfg {
//!     max_active: 4,
//!     threads: 4,
//!     sample: SampleCfg { max_new_tokens: 16, ..Default::default() },
//!     ..Default::default()
//! })?;
//! let prompts = ["Once upon a time", "Lily likes cats", "Jack went to"];
//! let requests: Vec<Request> = (0..8usize)
//!     .map(|i| Request::new(i as u64, prompts[i % prompts.len()]))
//!     .collect();
//! for c in sched.serve(&tok, requests)? {
//!     println!("#{} {} → {} ({:?})", c.request_id, c.prompt, c.completion, c.finish);
//! }
//! # Ok(())
//! # }
//! ```
//!
//! ## Serve over HTTP
//!
//! The same scheduler core serves cross-process through the
//! dependency-free HTTP front-end in [`server`]: a resident
//! [`serve::StreamScheduler`] keeps the worker pool alive between
//! requests and streams [`serve::TokenEvent`]s per request, so clients
//! see tokens the moment they are sampled.
//!
//! ```no_run
//! use std::sync::Arc;
//! use hsm::serve::{ServeCfg, StreamScheduler};
//! use hsm::server::HttpServer;
//! # use hsm::config::{LayerInfo, Manifest};
//! # use hsm::infer::{weights, Model, ModelWeights};
//! # use hsm::tokenizer::trainer as bpe;
//!
//! # fn main() -> anyhow::Result<()> {
//! # let layers = vec![LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 64 }];
//! # let tok = bpe::train(&hsm::corpus::generate(1234, 500), 300)?;
//! # let m = Manifest::synthetic("hsm_ab", layers, 32, 128, tok.vocab_size(), 1);
//! # let flat = weights::seeded_flat(&m, 42);
//! # let model = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat)?)?;
//! let sched = Arc::new(StreamScheduler::start(model, tok, ServeCfg::default())?);
//! let server = HttpServer::bind("127.0.0.1:8080", sched)?;
//! println!("listening on http://{}", server.local_addr());
//! server.join(); // park until shutdown
//! # Ok(())
//! # }
//! ```
//!
//! Then from any process (also via `hsm request`):
//!
//! ```bash
//! # whole completion at once
//! curl -s http://127.0.0.1:8080/v1/generate \
//!   -d '{"prompt": "Once upon a time", "id": 7, "max_new_tokens": 48}'
//! # per-token SSE stream (text_delta events, then done)
//! curl -sN http://127.0.0.1:8080/v1/stream \
//!   -d '{"prompt": "Once upon a time", "max_new_tokens": 48}'
//! ```
//!
//! Determinism crosses the wire: the request `id` fixes the RNG stream
//! (`seed ^ id`), so streamed bytes are identical to the in-process
//! scheduler and to sequential decoding.
//!
//! ## Prefix caching: shared prompt heads prefill once
//!
//! HSM layer state after consuming a prefix is a **fixed-size** set of
//! shift rings, so it can be snapshotted and forked
//! ([`infer::SessionState`], [`infer::DecodeSession::snapshot`] /
//! `restore` / `fork`) — unlike a KV cache, which grows with the
//! prefix.  Both schedulers exploit this with a shared
//! [`serve::PrefixCache`] (on by default;
//! [`serve::ServeCfg::prefix_cache_size`], CLI `hsm serve
//! --prefix-cache N`): requests sharing a prompt head restore the
//! head's snapshot and prefill only their tail, which is most of the
//! time-to-first-token for short completions.  Restores are bit-exact —
//! cached and cold decoding produce byte-identical text — and responses
//! report what happened:
//!
//! ```bash
//! curl -s http://127.0.0.1:8080/v1/generate \
//!   -d '{"prompt": "Once upon a time", "id": 7}'
//! # → {..., "cached_prefix_len": 4, "finish": "eot"}   (second call on)
//! curl -s http://127.0.0.1:8080/healthz
//! # → {..., "prefix_cache": {"hits": 1, "misses": 1, "hit_rate": 0.5, ...}}
//! ```
//!
//! `GET /healthz` exposes hit/miss/eviction counters, and
//! `cargo bench --bench prefix_cache` records cold-vs-hit TTFT into
//! `BENCH_prefix.json`.  Dropping a [`serve::TokenStream`] (or closing
//! the HTTP socket mid-stream) cancels the request at its next sampled
//! token ([`serve::FinishReason::Cancelled`]) instead of decoding
//! unobserved, and `Connection: keep-alive` is honored on
//! `/v1/generate` / `/healthz` ([`server::client::Client`] reuses one
//! connection across calls).
//!
//! ## Speculative decoding: more tokens per verify round, same bytes
//!
//! Forkable session state also powers speculative decoding
//! ([`infer::speculate`]): a cheap drafter proposes a block of tokens,
//! the full model scores the whole block on the sequence's own forked
//! state, and every scored position is sampled with the request's RNG
//! stream — so the emitted bytes are **identical** to plain decoding
//! (greedy trivially so), while accepted drafts emit several tokens
//! per full-model verify round.  Three drafters ship: `ngram`
//! (model-free prompt lookup — strong on repetitive/copy-heavy text),
//! `shallow` (the first K layers of the same shared-weight model), and
//! `shallow-q` (the same K layers drafting on a **quantized** shadow
//! of those weights — int8, or int4 when the serving model is int4 —
//! cheaper drafts, identical served bytes, because verification
//! always scores the serving model).
//! Enable with [`serve::ServeCfg::speculation`] or the CLI:
//!
//! ```bash
//! hsm serve --variant hsm_ab --checkpoint ck.bin --http 127.0.0.1:8080 \
//!     --speculate 4 --drafter ngram   # or: shallow:2 | shallow-q:2
//! hsm generate --variant hsm_ab --checkpoint ck.bin --speculate 4
//! curl -s http://127.0.0.1:8080/healthz
//! # → {..., "speculation": {"drafter": "ngram", "rounds": 12,
//! #       "accepted": 31, "tokens_per_round": 3.58, ...}}
//! ```
//!
//! Responses carry per-request acceptance accounting
//! ([`serve::Completion::spec`]), `rust/tests/spec_parity.rs` pins
//! byte-parity for every mixer kind × drafter × sampling mode, and
//! `cargo bench --bench speculative` records accepted-tokens-per-round
//! and end-to-end tok/s vs plain decoding into `BENCH_spec.json`.
//!
//! ## Performance: kernel tiers and the fused verify pass
//!
//! The native forward pass runs on a tiered kernel stack in
//! [`infer::tensor`]: a **naive** reference that defines the exact
//! per-element operation order, cache-tiled **blocked** scalar kernels
//! (the default hot path), explicit `std::arch` **AVX2** kernels behind
//! `--features simd` chosen by runtime CPU detection with a portable
//! chunked fallback ([`infer::tensor::kernel_backend`] says
//! which is live), an **int8** tier (`matvec_q` & co.) with the
//! same naive/blocked/AVX2 ladder for quantized weights, and an
//! **int4** tier (`matvec_q4` & co.) packing two weights per byte
//! with one f32 scale per 32-element group
//! ([`infer::tensor::Q4_GROUP`]).  Every tier
//! is **bit-identical** to its naive reference: no FMA,
//! vectorisation only across independent accumulation chains, and the
//! zero-tap row skip preserved — so the byte-exactness contracts
//! (decode/fork/stream/spec parity) hold under any tier, fuzzed by
//! `rust/tests/tensor_props.rs` on NaN/±0.0/subnormal inputs and
//! remainder-heavy shapes.
//!
//! Speculative verify rounds score the whole draft block + committed
//! token in **one fused batched pass** per layer
//! ([`infer::DecodeSession::step_batch`] /
//! [`infer::Decoder::step_batch`], reusable slab-allocated scratch,
//! [`infer::DecodeSession::rewind_batch`] to keep only the accepted
//! prefix) instead of draft+1 sequential steps with a snapshot per
//! position.  Same bytes; each weight matrix streams through cache
//! once per round.  On by default ([`infer::SpecCfg`]'s `fused`);
//! `fused: false` keeps the sequential path for A/B benching, and
//! `cargo bench --bench serve_throughput` records the kernel-tier and
//! batched-row timings into `BENCH_serve.json`.
//!
//! ## Performance: weight quantization (int8 / int4)
//!
//! `--precision int8 | int4` (CLI) or
//! [`infer::Model::shared_with_precision`] quantizes the resident
//! weights at load time — to **int8 with one f32 scale per output
//! row** ([`infer::QuantWeights`]) or to **packed int4 with one f32
//! scale per 32-weight group** ([`infer::Quant4Weights`],
//! [`infer::Precision`]) — checkpoints stay f32 on disk — and decodes
//! on the matching kernel tier.  A weight row costs `cols + 4` bytes
//! (int8) or `⌈cols/2⌉ + 4·⌈cols/32⌉` bytes (int4) instead of
//! `4·cols`, so the resident set shrinks to ~0.26–0.28× (int8) and
//! ~0.16× (int4) of f32 (asserted ≤ 0.30 / ≤ 0.20 by
//! `cargo bench --bench quantized`, which writes per-shape resident
//! bytes and tok/s into `BENCH_quant.json`):
//!
//! | dim  | f32 row | int8 row | ratio | int4 row | ratio |
//! |------|---------|----------|-------|----------|-------|
//! | 64   | 256 B   | 68 B     | 0.266 | 40 B     | 0.156 |
//! | 192  | 768 B   | 196 B    | 0.255 | 120 B    | 0.156 |
//! | 512  | 2048 B  | 516 B    | 0.252 | 320 B    | 0.156 |
//!
//! Activations stay int8 (one scale per row) at either precision, and
//! their quantization is **hoisted**: each post-LN row is quantized
//! once per layer into a reusable `(q, scale)` slab shared by every
//! quantized matvec that consumes it (attention's Q/K/V drop from
//! three `quantize_row` calls to one), on both the sequential `step`
//! and fused `step_batch` paths — bit-identical to per-call
//! quantization, A/B-timed with digest parity by the quantized bench.
//!
//! Quantized decoding is deterministic but **not** byte-identical to
//! f32; `rust/tests/quant_tolerance.rs` pins the drift for every mixer
//! kind (int8: relative logit delta ≤ 0.15, perplexity ratio ≤ 1.30,
//! greedy agreement ≥ 0.5 — healthy runs sit far inside all three;
//! int4 carries looser pins, 0.75 / 4.0 / 0.10) and proves both pin
//! sets trip on a corrupted quantizer.  When served bytes must not
//! move at all, keep the model f32 and put quantization on the
//! **drafter** instead: `--drafter shallow-q:K` drafts on a
//! lazily-quantized shadow of the first K layers (int4 models draft at
//! int4) while verification scores the serving model, so the output is
//! byte-identical to plain decoding (pinned by
//! `rust/tests/spec_parity.rs`) and quantization error can only cost
//! acceptance rate.  A serving stack declares its precision in
//! [`serve::ServeCfg`] (`precision`), cross-checked against the model
//! at construction, and `GET /healthz` reports
//! `model.{precision, kernel_backend, resident_weight_bytes}`.
//!
//! At a quantized serving precision the prefix cache stores snapshots
//! **in serving precision**: ring rows produced by quantized decoding
//! carry int8 activation images, and [`serve::PrefixCache`] compacts
//! a snapshot to those images at insert
//! ([`infer::SessionState::compact`]) and re-expands on lookup
//! ([`infer::SessionState::hydrate`]) — byte-exact restores, the
//! precision folded into the cache key, with resident bytes and
//! quantized-entry counts on `GET /healthz` and `GET /metrics`
//! (`hsm_prefix_cache_resident_bytes`,
//! `hsm_prefix_cache_quantized_entries`,
//! `hsm_model_resident_weight_bytes`).
//!
//! ## Observability: `/metrics`, latency histograms, request logs
//!
//! The serving stack records its own telemetry through the [`obs`]
//! subsystem ([`serve::ServeCfg`]'s `obs`, on by default): lock-free
//! log-bucketed latency histograms (queue wait, TTFT, per-token gap,
//! end-to-end, speculative verify rounds; ≤ 6.25% quantile error),
//! request/token/prefix-cache/speculation counters, and sampled
//! per-stage step timing (prefill vs step vs fused verify × mixer vs
//! FFN vs logits, keyed by mixer kind and precision).  The HTTP
//! front-end exposes the whole registry in Prometheus text format at
//! `GET /metrics`, and `GET /healthz` reads the same cells.  A
//! JSON-lines request-lifecycle log (`admitted` → `started` →
//! `first_token` → `finished`) lands wherever
//! `hsm serve --log-requests PATH` (or `ObsCfg::request_log`) points:
//!
//! ```no_run
//! use std::sync::Arc;
//! use hsm::obs::{MetricsRegistry, ObsCfg, RequestLog};
//! use hsm::serve::{ServeCfg, StreamScheduler};
//! use hsm::server::HttpServer;
//! # use hsm::config::{LayerInfo, Manifest};
//! # use hsm::infer::{weights, Model, ModelWeights};
//! # use hsm::tokenizer::trainer as bpe;
//!
//! # fn main() -> anyhow::Result<()> {
//! # let layers = vec![LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 64 }];
//! # let tok = bpe::train(&hsm::corpus::generate(1234, 500), 300)?;
//! # let m = Manifest::synthetic("hsm_ab", layers, 32, 128, tok.vocab_size(), 1);
//! # let flat = weights::seeded_flat(&m, 42);
//! # let model = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat)?)?;
//! let registry = MetricsRegistry::new();
//! let cfg = ServeCfg {
//!     obs: ObsCfg {
//!         metrics: Some(Arc::clone(&registry)),
//!         request_log: Some(RequestLog::to_file("requests.jsonl".as_ref())?),
//!         ..Default::default()
//!     },
//!     ..Default::default()
//! };
//! let sched = Arc::new(StreamScheduler::start(model, tok, cfg)?);
//! let server = HttpServer::bind("127.0.0.1:8080", sched)?;
//! // `curl -s localhost:8080/metrics` scrapes the registry; exact
//! // quantiles are also available in-process:
//! let p95_ttft_ns = registry.ttft.snapshot().quantile(0.95);
//! # let _ = (server, p95_ttft_ns);
//! # Ok(())
//! # }
//! ```
//!
//! Telemetry never changes served bytes (`cargo bench --bench
//! observability` asserts byte-parity and pins the overhead ≤ 3%,
//! writing `BENCH_obs.json`), and the decode hot path stays
//! allocation-free: counters are relaxed atomic adds, histograms are
//! sharded per worker, and stage timing reads the clock only on
//! sampled steps (`ObsCfg::stage_sample_every`).
//!
//! ## Load testing & SLOs: `hsm loadgen`, backpressure, quotas
//!
//! The serving stack enforces SLOs at admission — all off by default,
//! so served bytes are untouched until an operator opts in
//! ([`serve::ServeCfg`]):
//!
//! * **queue-depth backpressure** (`max_queue_depth`, CLI
//!   `--max-queue-depth`): when the resident scheduler's wait queue is
//!   full, [`serve::StreamScheduler::try_submit`] refuses with
//!   [`serve::AdmissionError::QueueFull`] and the HTTP front-end
//!   answers **429 Too Many Requests** with a `Retry-After` header
//!   sized from queue pressure — load sheds at the door instead of
//!   letting queue latency collapse for everyone;
//! * **per-user quotas** ([`serve::QuotaCfg`], CLI `--quota-requests` /
//!   `--quota-tokens` / `--quota-window-ms`): fixed-window request and
//!   token budgets keyed by the optional `user` field on
//!   [`serve::Request`] and the JSON API (`{"user": "alice", ...}`),
//!   charged pessimistically (prompt + budget) at admission and
//!   refused as 429 with `Retry-After` = the window remainder;
//! * **deadline-aware scheduling** (`edf`, CLI `--edf`): the wait queue
//!   orders earliest-deadline-first (per-request `deadline_ms`, else
//!   `max_queue_wait`), and expired jobs are reaped from anywhere in
//!   the queue at submit/poll time — not only when a worker pops them.
//!
//! Completion statuses say what actually happened: client errors
//! finish `rejected` (HTTP 400), capacity refusals `throttled` (429 +
//! `Retry-After`), queue-deadline expiries `timed_out` (503).
//! [`server::client::try_generate`] / [`server::client::try_stream`]
//! surface refusals as [`server::client::ApiOutcome::Throttled`] with
//! the parsed backoff.  Scheduling order never changes sampled bytes
//! (request ids fix the RNG streams), so EDF and backpressure are
//! text-safe; with every knob off the serving path is byte-identical
//! to previous releases.
//!
//! The open-loop [`loadgen`] harness measures all of it end to end:
//!
//! ```bash
//! hsm loadgen --seed 42 --requests 24 --rate 30 --out BENCH_load.json
//! hsm loadgen --addr 127.0.0.1:8080 --scenario streaming  # external server
//! ```
//!
//! Seeded Poisson arrivals, Zipf-distributed prompt reuse (exercising
//! the prefix cache), per-request `user`s (exercising quotas), three
//! scenarios (`short_chat` / `long_generation` / `streaming`).
//! p50/p95/p99 TTFT and queue wait plus tok/s come from differencing
//! the server's own `GET /metrics` around each run, and each
//! scenario's offered traffic is fingerprinted
//! ([`loadgen::schedule_digest`] — byte-deterministic per seed) so two
//! runs are provably comparable.  Admission control lands on
//! `/metrics` as `hsm_requests_throttled_total{cause=...}`,
//! `hsm_queue_depth` and `hsm_quota_tokens_charged_total`, and
//! `GET /healthz` reports the active SLO configuration.
//!
//! One-off generation keeps the simpler wrappers —
//! [`generation::generate`] (single session) and
//! [`generation::generate_batch`] (fixed membership) — which are thin
//! shims over the same scheduler core, so their outputs are byte-
//! identical to the threaded path.
//!
//! With artifacts (`make artifacts`), the same loop runs against trained
//! PJRT weights:
//!
//! ```bash
//! make artifacts                # python → artifacts/<preset>/<variant>/*
//! cargo run --release -- train --preset ci --variant hsm_ab --max-steps 200
//! cargo run --release -- generate --preset ci --variant hsm_ab \
//!     --engine native --samples 4 --prompt "Once upon a time"
//! cargo run --release -- report table1 --preset ci
//! ```

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod data;
pub mod generation;
pub mod infer;
pub mod loadgen;
pub mod obs;
pub mod report;
pub mod report_sinks;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod tokenizer;
pub mod util;

pub use config::{Manifest, TrainHp};
pub use coordinator::{TrainOutcome, Trainer, TrainerOptions};
pub use data::{Batch, Dataset};
pub use infer::{
    Decoder, DecodeSession, DrafterKind, Model, NativeDecoder, Precision, SessionState, SpecCfg,
    SpecStats,
};
pub use obs::{MetricsRegistry, ObsCfg, RequestLog};
pub use serve::{
    AdmissionError, Completion, PrefixCache, PrefixCacheStats, QuotaCfg, Request, Scheduler,
    ServeCfg, StreamScheduler, SubmitError, TokenEvent, TokenStream,
};
pub use server::HttpServer;
#[cfg(feature = "pjrt")]
pub use runtime::PjrtEngine;
pub use runtime::StepEngine;
pub use tokenizer::Tokenizer;
