//! # hsm — Hierarchical Shift Mixing
//!
//! A production-style reproduction of *"Hierarchical Shift Mixing — Beyond
//! Dense Attention in Transformers"* (Forchheimer, 2026).
//!
//! HSM replaces the dense softmax-attention mixer of a GPT-style decoder
//! with pairwise token mixing at layer-doubling temporal shifts, giving
//! linear-time complexity while covering multi-scale token interactions
//! across the layer stack.  This crate is **layer 3** of a three-layer
//! stack:
//!
//! * **L1** — Pallas kernels (shift-mix, causal flash attention, gated
//!   combine) authored in `python/compile/kernels/`.
//! * **L2** — the JAX decoder with all twelve mixer variants in
//!   `python/compile/model.py`, AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: tokenizer, corpus, data pipeline, the PJRT
//!   runtime that executes the artifacts, the training coordinator,
//!   generation, and the experiment/report drivers that regenerate every
//!   table and figure of the paper.
//!
//! Python never runs on the training or inference path: `make artifacts`
//! lowers the model once, and the `hsm` binary is self-contained
//! afterwards.
//!
//! ## Quick start
//!
//! ```bash
//! make artifacts                # python → artifacts/<preset>/<variant>/*
//! cargo run --release -- train --preset ci --variant hsm_ab --steps 200
//! cargo run --release -- generate --preset ci --variant hsm_ab \
//!     --prompt "Once upon a time"
//! cargo run --release -- report table1 --preset ci
//! ```

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod data;
pub mod generation;
pub mod infer;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod tokenizer;
pub mod util;

pub use config::{Manifest, TrainHp};
pub use coordinator::{TrainOutcome, Trainer, TrainerOptions};
pub use data::{Batch, Dataset};
pub use runtime::{PjrtEngine, StepEngine};
pub use tokenizer::Tokenizer;
