//! Open-loop load generator for the HTTP serving front-end — the
//! `hsm loadgen` subcommand.
//!
//! The generator drives a running `hsm serve --http` server (or a
//! self-hosted loopback instance with synthetic weights) with a
//! **seeded, deterministic** request schedule:
//!
//! * arrivals are Poisson — exponential inter-arrival gaps
//!   `-ln(1-u)/rate`, accumulated into absolute millisecond offsets —
//!   fired *open-loop*: one thread per request sleeps until its arrival
//!   time, so a slow server never throttles the offered load (that is
//!   the difference between measuring latency and measuring the
//!   generator);
//! * prompts are drawn Zipf-distributed from a small pool, so popular
//!   prompt heads repeat and the scheduler's [`PrefixCache`] sees
//!   realistic reuse;
//! * each request carries a `user` drawn uniformly from a small user
//!   set, exercising per-user quota enforcement when the server has it
//!   on.
//!
//! Three built-in scenarios cover the serving envelope: `short_chat`
//! (many small completions), `long_generation` (fewer, larger budgets),
//! and `streaming` (per-token SSE delivery).  For a fixed seed the
//! schedule is byte-deterministic — [`schedule_digest`] fingerprints it
//! and lands in the report so two runs are provably driving identical
//! traffic.  Latency quantiles (TTFT, queue wait) and token throughput
//! come from differencing the server's own `GET /metrics` exposition
//! around the run, not from client-side clocks — the numbers in
//! `BENCH_load.json` are the same ones an operator's scraper would see.
//!
//! [`PrefixCache`]: crate::serve::PrefixCache

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{LayerInfo, Manifest};
use crate::corpus;
use crate::infer::{weights, Model, ModelWeights};
use crate::serve::{FinishReason, ServeCfg, StreamScheduler};
use crate::server::api::GenerateRequest;
use crate::server::{client, HttpServer};
use crate::tokenizer::trainer as tok_trainer;
use crate::util::hash;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Schedule synthesis.

/// One traffic scenario: how many requests, at what rate, with what
/// prompt-reuse skew and token budgets.
#[derive(Clone, Debug)]
pub struct ScenarioCfg {
    pub name: String,
    /// Total requests fired.
    pub requests: usize,
    /// Poisson arrival rate (requests per second).
    pub rate_per_s: f64,
    /// Zipf exponent for prompt selection (larger → more reuse of the
    /// most popular prompts; 0 → uniform).
    pub zipf_s: f64,
    /// Distinct prompts in the pool.
    pub pool_size: usize,
    /// Distinct `user` identities cycling through the traffic.
    pub users: usize,
    /// Per-request `max_new_tokens`, drawn uniformly from this
    /// inclusive range.
    pub min_new_tokens: usize,
    pub max_new_tokens: usize,
    /// `/v1/stream` (SSE) instead of `/v1/generate`.
    pub stream: bool,
}

/// One scheduled request: fire at `at_ms` after the scenario starts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Arrival {
    pub at_ms: u64,
    pub id: u64,
    pub user: String,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub stream: bool,
}

/// The default scenario grid: short interactive chat, long-form
/// generation at half the rate, and streaming delivery.
pub fn builtin_scenarios(requests: usize, rate_per_s: f64) -> Vec<ScenarioCfg> {
    let base = ScenarioCfg {
        name: String::new(),
        requests: requests.max(1),
        rate_per_s: rate_per_s.max(0.1),
        zipf_s: 1.1,
        pool_size: 12,
        users: 4,
        min_new_tokens: 4,
        max_new_tokens: 8,
        stream: false,
    };
    vec![
        ScenarioCfg { name: "short_chat".into(), ..base.clone() },
        ScenarioCfg {
            name: "long_generation".into(),
            requests: requests.div_ceil(2).max(1),
            rate_per_s: (rate_per_s / 2.0).max(0.1),
            zipf_s: 0.9,
            pool_size: 6,
            users: 2,
            min_new_tokens: 24,
            max_new_tokens: 40,
            ..base.clone()
        },
        ScenarioCfg {
            name: "streaming".into(),
            pool_size: 8,
            min_new_tokens: 8,
            max_new_tokens: 16,
            stream: true,
            ..base
        },
    ]
}

/// Normalised Zipf CDF over ranks `1..=n`: `P(rank r) ∝ 1/r^s`.
fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut acc = 0.0;
    let mut cdf: Vec<f64> = (1..=n.max(1))
        .map(|r| {
            acc += 1.0 / (r as f64).powf(s);
            acc
        })
        .collect();
    for c in &mut cdf {
        *c /= acc;
    }
    cdf
}

/// Invert the CDF at `u ∈ [0, 1)`.
fn zipf_pick(cdf: &[f64], u: f64) -> usize {
    cdf.iter().position(|&c| u < c).unwrap_or(cdf.len() - 1)
}

/// Draw `n` prompts from the synthetic corpus: word windows, so every
/// byte is in-distribution for a corpus-trained tokenizer.
fn prompt_pool(n: usize, rng: &mut Rng) -> Vec<String> {
    let text = corpus::generate(rng.next_u64(), 16);
    let words: Vec<&str> = text.split_whitespace().collect();
    (0..n.max(1))
        .map(|_| {
            let len = 3 + rng.below(5);
            let start = rng.below(words.len().saturating_sub(len).max(1));
            words[start..(start + len).min(words.len())].join(" ")
        })
        .collect()
}

/// Synthesise the full arrival schedule for one scenario.  Pure
/// function of `(cfg, seed)` — same inputs, byte-identical output.
pub fn schedule(cfg: &ScenarioCfg, seed: u64) -> Vec<Arrival> {
    let mut tag = hash::FNV_OFFSET;
    hash::fold_bytes(&mut tag, cfg.name.as_bytes());
    let mut rng = Rng::new(seed ^ tag);
    let pool = prompt_pool(cfg.pool_size, &mut rng);
    let cdf = zipf_cdf(pool.len(), cfg.zipf_s);
    let span = cfg.max_new_tokens.saturating_sub(cfg.min_new_tokens);
    let mut at = 0.0f64;
    (0..cfg.requests)
        .map(|i| {
            // Exponential inter-arrival gap: -ln(1-u)/λ, u ∈ [0, 1).
            at += -(1.0 - rng.f64()).ln() / cfg.rate_per_s * 1e3;
            Arrival {
                at_ms: at as u64,
                id: i as u64,
                user: format!("user-{}", rng.below(cfg.users.max(1))),
                prompt: pool[zipf_pick(&cdf, rng.f64())].clone(),
                max_new_tokens: cfg.min_new_tokens + rng.below(span + 1),
                stream: cfg.stream,
            }
        })
        .collect()
}

/// FNV-1a fingerprint of a schedule — every field of every arrival.
/// Lands in the report so two runs can prove they offered identical
/// traffic even though measured latencies differ.
pub fn schedule_digest(arrivals: &[Arrival]) -> u64 {
    let mut h = hash::FNV_OFFSET;
    for a in arrivals {
        hash::fold(&mut h, a.at_ms);
        hash::fold(&mut h, a.id);
        hash::fold_bytes(&mut h, a.user.as_bytes());
        hash::fold_bytes(&mut h, a.prompt.as_bytes());
        hash::fold(&mut h, a.max_new_tokens as u64);
        hash::fold(&mut h, a.stream as u64);
    }
    h
}

// ---------------------------------------------------------------------------
// Prometheus scraping: parse, difference, extract quantiles.

/// A parsed `/metrics` exposition: plain samples by full series name,
/// histogram buckets by family (cumulative, sorted by `le`, `+Inf`
/// included).
#[derive(Debug, Default, Clone)]
pub struct MetricsSnapshot {
    counters: BTreeMap<String, f64>,
    hists: BTreeMap<String, Vec<(f64, u64)>>,
}

/// The value of `key` in a `{k="v",...}` label suffix.
fn label_value<'a>(labels: &'a str, key: &str) -> Option<&'a str> {
    let rest = labels.strip_suffix('}')?;
    for part in rest.split(',') {
        let (k, v) = part.split_once('=')?;
        if k.trim() == key {
            return Some(v.trim().trim_matches('"'));
        }
    }
    None
}

impl MetricsSnapshot {
    /// Parse Prometheus text exposition.  Unparseable lines are
    /// skipped — the scraper needs a few well-formed families, not a
    /// validator.
    pub fn parse(text: &str) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((series, value)) = line.rsplit_once(' ') else { continue };
            let Ok(value) = value.trim().parse::<f64>() else { continue };
            if let Some((base, labels)) = series.split_once('{') {
                if let (Some(family), Some(le)) =
                    (base.strip_suffix("_bucket"), label_value(labels, "le"))
                {
                    let le =
                        if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap_or(f64::NAN) };
                    if !le.is_nan() {
                        snap.hists.entry(family.to_string()).or_default().push((le, value as u64));
                        continue;
                    }
                }
            }
            snap.counters.insert(series.to_string(), value);
        }
        for buckets in snap.hists.values_mut() {
            buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        snap
    }

    /// Scrape and parse `GET /metrics` from a running server.
    pub fn scrape(addr: &str) -> Result<MetricsSnapshot> {
        Ok(MetricsSnapshot::parse(&client::metrics_text(addr)?))
    }

    /// A plain sample by its full series name (0 when absent).
    pub fn counter(&self, series: &str) -> f64 {
        self.counters.get(series).copied().unwrap_or(0.0)
    }

    /// Cumulative histogram count at upper bound `le`.  The renderer
    /// elides buckets no observation has reached, so absent buckets
    /// inherit the count of the nearest rendered bound below.
    fn cum_at(&self, family: &str, le: f64) -> u64 {
        let Some(buckets) = self.hists.get(family) else { return 0 };
        buckets.iter().rev().find(|&&(b, _)| b <= le).map(|&(_, c)| c).unwrap_or(0)
    }
}

/// Quantiles (in seconds) of the observations a histogram family gained
/// between two snapshots: per-bucket cumulative subtraction, then
/// `q`-quantile = upper bound of the first bucket whose cumulative
/// delta reaches `ceil(q · total)`.  Returns one value per requested
/// `q` (0 when nothing landed; the largest finite bound when the mass
/// sits in the `+Inf` bucket).
pub fn delta_quantiles(
    before: &MetricsSnapshot,
    after: &MetricsSnapshot,
    family: &str,
    qs: &[f64],
) -> Vec<f64> {
    let empty = Vec::new();
    let buckets = after.hists.get(family).unwrap_or(&empty);
    let deltas: Vec<(f64, u64)> = buckets
        .iter()
        .map(|&(le, cum)| (le, cum.saturating_sub(before.cum_at(family, le))))
        .collect();
    let total = deltas.last().map(|&(_, c)| c).unwrap_or(0);
    let largest_finite =
        deltas.iter().rev().map(|&(le, _)| le).find(|le| le.is_finite()).unwrap_or(0.0);
    qs.iter()
        .map(|&q| {
            if total == 0 {
                return 0.0;
            }
            let target = (q * total as f64).ceil().max(1.0) as u64;
            match deltas.iter().find(|&&(_, c)| c >= target) {
                Some(&(le, _)) if le.is_finite() => le,
                _ => largest_finite,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Execution.

/// What one fired request came back as.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fired {
    Ok,
    Throttled,
    Rejected,
    TimedOut,
    Error,
}

fn fire(addr: &str, a: &Arrival) -> Fired {
    let mut req = GenerateRequest::new(&a.prompt);
    req.max_new_tokens = Some(a.max_new_tokens);
    req.user = Some(a.user.clone());
    let outcome = if a.stream {
        client::try_stream(addr, &req, |_, _| {})
    } else {
        client::try_generate(addr, &req)
    };
    match outcome {
        Ok(client::ApiOutcome::Done(c)) => match c.finish {
            FinishReason::TimedOut => Fired::TimedOut,
            FinishReason::Rejected(_) => Fired::Rejected,
            FinishReason::Throttled(_) => Fired::Throttled,
            _ => Fired::Ok,
        },
        Ok(client::ApiOutcome::Throttled { .. }) => Fired::Throttled,
        Err(_) => Fired::Error,
    }
}

/// Measured outcome of one scenario run.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    pub name: String,
    /// [`schedule_digest`] of the traffic this run offered.
    pub digest: u64,
    pub sent: usize,
    pub completed: usize,
    pub throttled: usize,
    pub rejected: usize,
    pub timed_out: usize,
    pub errors: usize,
    pub wall_seconds: f64,
    pub tokens_generated: u64,
    pub tok_per_s: f64,
    /// p50/p95/p99 time-to-first-token, milliseconds.
    pub ttft_ms: [f64; 3],
    /// p50/p95/p99 admission queue wait, milliseconds.
    pub queue_wait_ms: [f64; 3],
}

const QS: [f64; 3] = [0.50, 0.95, 0.99];

/// Run one scenario against `addr`: synthesise the schedule, fire it
/// open-loop (one thread per arrival, each sleeping to its offset), and
/// difference the server's `/metrics` around the run.
pub fn run_scenario(addr: &str, cfg: &ScenarioCfg, seed: u64) -> Result<ScenarioOutcome> {
    let arrivals = schedule(cfg, seed);
    let digest = schedule_digest(&arrivals);
    let before = MetricsSnapshot::scrape(addr)?;
    let t0 = Instant::now();
    let fired: Vec<Fired> = std::thread::scope(|s| {
        let handles: Vec<_> = arrivals
            .iter()
            .map(|a| {
                s.spawn(move || {
                    let dt = Duration::from_millis(a.at_ms).saturating_sub(t0.elapsed());
                    if !dt.is_zero() {
                        std::thread::sleep(dt);
                    }
                    fire(addr, a)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(Fired::Error)).collect()
    });
    let wall_seconds = t0.elapsed().as_secs_f64();
    let after = MetricsSnapshot::scrape(addr)?;

    let count = |want: Fired| fired.iter().filter(|&&f| f == want).count();
    let tokens = (after.counter("hsm_tokens_generated_total")
        - before.counter("hsm_tokens_generated_total"))
        .max(0.0) as u64;
    let to_ms = |v: Vec<f64>| [v[0] * 1e3, v[1] * 1e3, v[2] * 1e3];
    Ok(ScenarioOutcome {
        name: cfg.name.clone(),
        digest,
        sent: fired.len(),
        completed: count(Fired::Ok),
        throttled: count(Fired::Throttled),
        rejected: count(Fired::Rejected),
        timed_out: count(Fired::TimedOut),
        errors: count(Fired::Error),
        wall_seconds,
        tokens_generated: tokens,
        tok_per_s: tokens as f64 / wall_seconds.max(1e-9),
        ttft_ms: to_ms(delta_quantiles(&before, &after, "hsm_ttft_seconds", &QS)),
        queue_wait_ms: to_ms(delta_quantiles(&before, &after, "hsm_queue_wait_seconds", &QS)),
    })
}

/// Run every scenario in order against one server.
pub fn run(addr: &str, scenarios: &[ScenarioCfg], seed: u64) -> Result<Vec<ScenarioOutcome>> {
    scenarios.iter().map(|cfg| run_scenario(addr, cfg, seed)).collect()
}

/// Render outcomes as the `BENCH_load.json` document.
pub fn report_json(seed: u64, outcomes: &[ScenarioOutcome]) -> Value {
    let r3 = |x: f64| (x * 1e3).round() / 1e3;
    let quant = |v: &[f64; 3]| {
        json::obj(vec![
            ("p50", json::num(r3(v[0]))),
            ("p95", json::num(r3(v[1]))),
            ("p99", json::num(r3(v[2]))),
        ])
    };
    json::obj(vec![
        ("bench", json::s("load")),
        ("seed", json::num(seed as f64)),
        (
            "scenarios",
            json::arr(
                outcomes
                    .iter()
                    .map(|o| {
                        json::obj(vec![
                            ("name", json::s(&o.name)),
                            ("schedule_digest", json::s(&format!("{:016x}", o.digest))),
                            ("requests", json::num(o.sent as f64)),
                            ("completed", json::num(o.completed as f64)),
                            ("throttled", json::num(o.throttled as f64)),
                            ("rejected", json::num(o.rejected as f64)),
                            ("timed_out", json::num(o.timed_out as f64)),
                            ("errors", json::num(o.errors as f64)),
                            ("wall_seconds", json::num(r3(o.wall_seconds))),
                            ("tokens_generated", json::num(o.tokens_generated as f64)),
                            ("tok_per_s", json::num(r3(o.tok_per_s))),
                            ("ttft_ms", quant(&o.ttft_ms)),
                            ("queue_wait_ms", quant(&o.queue_wait_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

// ---------------------------------------------------------------------------
// Self-hosted loopback target.

/// A loopback serving target the generator owns: synthetic two-layer
/// HSM weights, corpus-trained tokenizer, real accept loop on an
/// OS-assigned port.  Artifact-free and deterministic — `hsm loadgen`
/// without `--addr` measures this.
pub struct SelfHosted {
    server: HttpServer,
    addr: String,
}

impl SelfHosted {
    /// Spin up the loopback server with `cfg`'s scheduling/SLO knobs
    /// (sampling defaults are filled in if left at zero).
    pub fn start(cfg: ServeCfg) -> Result<SelfHosted> {
        let text = corpus::generate(9, 80);
        let tok = tok_trainer::train(&text, 300).map_err(|e| anyhow!("{e}"))?;
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
        ];
        let m = Manifest::synthetic("hsm_ab", layers, 8, 256, tok.vocab_size(), 1);
        let flat = weights::seeded_flat(&m, 21);
        let model = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat)?)?;
        let sched = Arc::new(StreamScheduler::start(model, tok, cfg)?);
        let server = HttpServer::bind("127.0.0.1:0", sched)?;
        let addr = server.local_addr().to_string();
        Ok(SelfHosted { server, addr })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn shutdown(&self) {
        self.server.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ScenarioCfg {
        ScenarioCfg {
            name: "unit".into(),
            requests: 40,
            rate_per_s: 25.0,
            zipf_s: 1.1,
            pool_size: 8,
            users: 3,
            min_new_tokens: 4,
            max_new_tokens: 8,
            stream: false,
        }
    }

    /// Property: for any seed the schedule is a pure function of
    /// `(cfg, seed)` — regenerating it gives byte-identical arrivals
    /// and the same digest; distinct seeds give distinct schedules.
    #[test]
    fn schedule_is_byte_deterministic_for_a_fixed_seed() {
        let cfg = cfg();
        let mut digests = Vec::new();
        for seed in 0..16u64 {
            let a = schedule(&cfg, seed);
            let b = schedule(&cfg, seed);
            assert_eq!(a, b, "seed {seed}: schedule must be reproducible");
            assert_eq!(schedule_digest(&a), schedule_digest(&b));
            digests.push(schedule_digest(&a));
        }
        digests.sort_unstable();
        digests.dedup();
        assert_eq!(digests.len(), 16, "distinct seeds must give distinct schedules");
    }

    #[test]
    fn schedule_respects_scenario_bounds() {
        let cfg = cfg();
        let arrivals = schedule(&cfg, 7);
        assert_eq!(arrivals.len(), cfg.requests);
        let mut prev = 0u64;
        for a in &arrivals {
            assert!(a.at_ms >= prev, "arrivals must be time-ordered");
            prev = a.at_ms;
            assert!((cfg.min_new_tokens..=cfg.max_new_tokens).contains(&a.max_new_tokens));
            assert!(!a.prompt.is_empty());
            let user_ix: usize = a.user.strip_prefix("user-").unwrap().parse().unwrap();
            assert!(user_ix < cfg.users);
        }
    }

    #[test]
    fn zipf_cdf_is_monotone_and_skewed_toward_low_ranks() {
        let cdf = zipf_cdf(10, 1.1);
        assert_eq!(cdf.len(), 10);
        assert!(cdf.windows(2).all(|w| w[0] < w[1]));
        assert!((cdf.last().unwrap() - 1.0).abs() < 1e-12);
        // Rank 1 carries more mass than rank 10 by construction.
        assert!(cdf[0] > cdf[9] - cdf[8]);
        // Inversion: u below the first step picks rank 0, u near 1 the tail.
        assert_eq!(zipf_pick(&cdf, 0.0), 0);
        assert_eq!(zipf_pick(&cdf, 0.999_999), 9);
    }

    const BEFORE: &str = "\
# HELP hsm_ttft_seconds x
# TYPE hsm_ttft_seconds histogram
hsm_ttft_seconds_bucket{le=\"0.005\"} 2
hsm_ttft_seconds_bucket{le=\"+Inf\"} 2
hsm_ttft_seconds_sum 0.004
hsm_ttft_seconds_count 2
hsm_tokens_generated_total 10
";

    const AFTER: &str = "\
hsm_ttft_seconds_bucket{le=\"0.005\"} 3
hsm_ttft_seconds_bucket{le=\"0.05\"} 6
hsm_ttft_seconds_bucket{le=\"+Inf\"} 7
hsm_ttft_seconds_sum 0.4
hsm_ttft_seconds_count 7
hsm_tokens_generated_total 50
";

    /// Bucket elision across snapshots: `le="0.05"` is absent before
    /// (nothing had reached it), so its before-count is inherited from
    /// the bound below, and the deltas come out right.
    #[test]
    fn metrics_delta_quantiles_handle_elided_buckets() {
        let before = MetricsSnapshot::parse(BEFORE);
        let after = MetricsSnapshot::parse(AFTER);
        assert_eq!(before.cum_at("hsm_ttft_seconds", 0.05), 2);
        // Deltas: ≤5ms → 1, ≤50ms → 4, total 5.
        let q = delta_quantiles(&before, &after, "hsm_ttft_seconds", &[0.2, 0.5, 0.99]);
        assert_eq!(q[0], 0.005, "p20 target is the 1st observation");
        assert_eq!(q[1], 0.05, "p50 target is the 3rd observation");
        // p99 lands in the +Inf bucket → clamped to the largest finite bound.
        assert_eq!(q[2], 0.05);
        let tokens = after.counter("hsm_tokens_generated_total")
            - before.counter("hsm_tokens_generated_total");
        assert_eq!(tokens, 40.0);
    }

    #[test]
    fn delta_quantiles_of_an_idle_family_are_zero() {
        let snap = MetricsSnapshot::parse(BEFORE);
        assert_eq!(delta_quantiles(&snap, &snap, "hsm_ttft_seconds", &QS), vec![0.0, 0.0, 0.0]);
        assert_eq!(delta_quantiles(&snap, &snap, "hsm_absent_seconds", &QS), vec![0.0, 0.0, 0.0]);
    }

    /// The report document serializes the digest as fixed-width hex (a
    /// u64 does not survive an f64 round-trip) and keeps scenario order.
    #[test]
    fn report_json_carries_digests_and_quantiles() {
        let o = ScenarioOutcome {
            name: "short_chat".into(),
            digest: 0xdead_beef_0000_0001,
            sent: 10,
            completed: 8,
            throttled: 2,
            rejected: 0,
            timed_out: 0,
            errors: 0,
            wall_seconds: 1.25,
            tokens_generated: 64,
            tok_per_s: 51.2,
            ttft_ms: [5.0, 25.0, 100.0],
            queue_wait_ms: [1.0, 10.0, 50.0],
        };
        let v = report_json(42, &[o]);
        let text = v.to_string();
        assert!(text.contains("\"schedule_digest\":\"deadbeef00000001\""), "got: {text}");
        let sc = &v.get("scenarios").as_arr().unwrap()[0];
        assert_eq!(sc.get("ttft_ms").get("p95").as_f64(), Some(25.0));
        assert_eq!(sc.get("throttled").as_usize(), Some(2));
        assert_eq!(v.get("seed").as_usize(), Some(42));
    }
}
