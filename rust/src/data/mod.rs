//! Dataset pipeline: corpus text → tokenized, filtered, split, batched.
//!
//! Mirrors the paper's §6.2 protocol:
//!
//! * stories shorter than the context window are **filtered out**
//!   (footnote 7);
//! * the remainder is split 90 % train / 10 % validation;
//! * training examples are `(x, y)` windows of `ctx` tokens where
//!   `y[t] = x[t+1]` (next-token prediction);
//! * batches are reshuffled every epoch with a seeded RNG, so runs are
//!   reproducible.
//!
//! Each story contributes non-overlapping windows and ends with the
//! end-of-text sentinel so the model learns document boundaries.

use anyhow::{bail, Result};

use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// One training batch in the layout the runtime uploads: row-major
/// `[batch, ctx]` i32.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub ctx: usize,
}

/// A tokenized split: every sequence has exactly `ctx + 1` tokens.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub sequences: Vec<Vec<u32>>,
    pub ctx: usize,
}

/// Statistics from dataset construction (logged + asserted in tests).
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    pub stories_total: usize,
    pub stories_filtered: usize,
    pub windows: usize,
    pub tokens: usize,
}

impl Dataset {
    /// Tokenize `corpus` (one story per line), filter, window and split.
    pub fn build(
        corpus: &str,
        tok: &Tokenizer,
        ctx: usize,
        train_frac: f64,
        seed: u64,
    ) -> Result<(Dataset, Dataset, BuildStats)> {
        if !(0.0..=1.0).contains(&train_frac) {
            bail!("train_frac must be in [0, 1]");
        }
        let mut stats = BuildStats::default();
        let mut windows: Vec<Vec<u32>> = Vec::new();
        for story in corpus.lines() {
            let story = story.trim();
            if story.is_empty() {
                continue;
            }
            stats.stories_total += 1;
            let mut ids = tok.encode(story);
            ids.push(tok.eot);
            stats.tokens += ids.len();
            // Paper footnote 7: drop stories shorter than the context window.
            if ids.len() < ctx + 1 {
                stats.stories_filtered += 1;
                continue;
            }
            for w in ids.chunks_exact(ctx + 1) {
                windows.push(w.to_vec());
            }
        }
        stats.windows = windows.len();
        if windows.is_empty() {
            bail!(
                "no training windows: every story shorter than ctx+1={} tokens",
                ctx + 1
            );
        }
        // Deterministic shuffle before the split so both splits are i.i.d.
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut windows);
        let n_train = ((windows.len() as f64) * train_frac).round() as usize;
        let val = windows.split_off(n_train.min(windows.len()));
        Ok((
            Dataset { sequences: windows, ctx },
            Dataset { sequences: val, ctx },
            stats,
        ))
    }

    pub fn len(&self) -> usize {
        self.sequences.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sequences.is_empty()
    }

    /// Number of full batches per epoch at the given batch size.
    pub fn batches_per_epoch(&self, batch: usize) -> usize {
        self.len() / batch
    }

    /// Assemble one batch from sequence indices.
    fn gather(&self, idxs: &[usize]) -> Batch {
        let ctx = self.ctx;
        let mut x = Vec::with_capacity(idxs.len() * ctx);
        let mut y = Vec::with_capacity(idxs.len() * ctx);
        for &i in idxs {
            let seq = &self.sequences[i];
            x.extend(seq[..ctx].iter().map(|&t| t as i32));
            y.extend(seq[1..ctx + 1].iter().map(|&t| t as i32));
        }
        Batch { x, y, batch: idxs.len(), ctx }
    }

    /// Iterator over one epoch of shuffled full batches.
    pub fn epoch(&self, batch: usize, seed: u64) -> EpochIter<'_> {
        let mut order: Vec<usize> = (0..self.len()).collect();
        Rng::new(seed).shuffle(&mut order);
        EpochIter { ds: self, order, batch, pos: 0 }
    }

    /// Deterministic (unshuffled) batches — used for validation.
    pub fn batches(&self, batch: usize) -> EpochIter<'_> {
        EpochIter {
            ds: self,
            order: (0..self.len()).collect(),
            batch,
            pos: 0,
        }
    }
}

/// Iterator yielding full `[batch, ctx]` batches (remainder dropped).
pub struct EpochIter<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl<'a> Iterator for EpochIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let idxs = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        Some(self.ds.gather(idxs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus;
    use crate::tokenizer::trainer;
    use crate::util::prop;

    fn setup(ctx: usize) -> (Dataset, Dataset, BuildStats, Tokenizer) {
        let text = corpus::generate(11, 120);
        let tok = trainer::train(&text, 400).unwrap();
        let (tr, va, st) = Dataset::build(&text, &tok, ctx, 0.9, 42).unwrap();
        (tr, va, st, tok)
    }

    #[test]
    fn windows_have_exact_length() {
        let (tr, va, _, _) = setup(32);
        for seq in tr.sequences.iter().chain(&va.sequences) {
            assert_eq!(seq.len(), 33);
        }
    }

    #[test]
    fn split_fractions_roughly_honored() {
        let (tr, va, st, _) = setup(32);
        let total = tr.len() + va.len();
        assert_eq!(total, st.windows);
        let frac = tr.len() as f64 / total as f64;
        assert!((frac - 0.9).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn no_leakage_between_splits() {
        let (tr, va, _, _) = setup(32);
        let train_set: std::collections::HashSet<&Vec<u32>> = tr.sequences.iter().collect();
        // Identical windows can legitimately exist in both splits only if
        // the same token window occurs twice in the corpus; with 120
        // distinct stories that's essentially impossible.
        let dup = va.sequences.iter().filter(|s| train_set.contains(s)).count();
        assert_eq!(dup, 0);
    }

    #[test]
    fn batch_is_next_token_shifted() {
        let (tr, _, _, _) = setup(16);
        let b = tr.batches(2).next().unwrap();
        assert_eq!(b.x.len(), 2 * 16);
        for row in 0..2 {
            let x = &b.x[row * 16..(row + 1) * 16];
            let y = &b.y[row * 16..(row + 1) * 16];
            assert_eq!(&x[1..], &y[..15], "y must be x shifted by one");
        }
    }

    #[test]
    fn epoch_shuffling_is_seeded_and_complete() {
        let (tr, _, _, _) = setup(16);
        let a: Vec<Batch> = tr.epoch(4, 1).collect();
        let b: Vec<Batch> = tr.epoch(4, 1).collect();
        let c: Vec<Batch> = tr.epoch(4, 2).collect();
        assert_eq!(a, b, "same seed must give same epoch");
        assert_ne!(a, c, "different seeds must differ");
        assert_eq!(a.len(), tr.batches_per_epoch(4));
    }

    #[test]
    fn short_stories_filtered() {
        let tok = trainer::train("tiny story words here", 280).unwrap();
        let corpus = "short\nanother short one\n";
        let err = Dataset::build(corpus, &tok, 64, 0.9, 0);
        assert!(err.is_err(), "all-short corpus must fail loudly");
    }

    #[test]
    fn tokens_in_vocab_property() {
        let (tr, _, _, tok) = setup(24);
        prop::check_n("tokens-in-vocab", 16, |rng| {
            let i = rng.below(tr.len());
            for &t in &tr.sequences[i] {
                assert!((t as usize) < tok.vocab_size());
            }
        });
    }
}
