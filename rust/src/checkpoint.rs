//! Checkpointing: a minimal safetensors-like binary container.
//!
//! Format (little-endian):
//!
//! ```text
//! magic  "HSMCKPT1"                     8 bytes
//! header_len: u64                       JSON header length
//! header: JSON                          { "meta": {...}, "tensors": [
//!                                         {"name", "shape", "offset", "len"}... ] }
//! payload: f32 data, tensor-by-tensor   (offsets relative to payload start)
//! ```
//!
//! Stores model parameters, optimizer moments and the step counter so a
//! training run resumes bit-exactly (the step counter doubles as the
//! dropout seed — see `python/compile/steps.py`).  Since v0.3 the full
//! [`Manifest`] is embedded as a JSON meta entry, so the native decoder
//! (and `hsm generate/serve --engine native`) can run straight from a
//! checkpoint with **no PJRT artifact directory** — see
//! [`Checkpoint::manifest`].

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Manifest;
use crate::util::json::{self, Value};

const MAGIC: &[u8; 8] = b"HSMCKPT1";

/// A checkpoint: named f32 tensors plus metadata.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    pub meta: Vec<(String, String)>,
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    /// Assemble a training checkpoint from engine state.  Tensor names
    /// and shapes come from the manifest (its `params` list IS the flat
    /// parameter order), and a full manifest snapshot is embedded so the
    /// checkpoint is self-describing for artifact-free native inference.
    pub fn from_training(
        manifest: &Manifest,
        step: usize,
        params: Vec<Vec<f32>>,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    ) -> Self {
        let mut ck = Checkpoint::default();
        ck.meta.push(("variant".into(), manifest.variant.clone()));
        ck.meta.push(("preset".into(), manifest.preset.clone()));
        ck.meta.push(("step".into(), step.to_string()));
        ck.meta.push(("manifest".into(), manifest.to_json().to_string()));
        for (group, tensors) in [("param", params), ("m", m), ("v", v)] {
            // Fail at write time, not as a missing-tensor error on load.
            assert_eq!(
                tensors.len(),
                manifest.params.len(),
                "checkpoint group {group:?} has {} tensors, manifest expects {}",
                tensors.len(),
                manifest.params.len()
            );
            for (p, data) in manifest.params.iter().zip(tensors) {
                ck.tensors.push((format!("{group}/{}", p.name), p.shape.clone(), data));
            }
        }
        ck
    }

    pub fn meta_value(&self, key: &str) -> Option<&str> {
        self.meta.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// The embedded manifest snapshot, when present.
    ///
    /// `Ok(None)` means a pre-v0.3 checkpoint with no snapshot — callers
    /// that need artifact-free loading should surface that as "re-train
    /// or point at an artifact directory".  A snapshot that fails to
    /// parse is an error (the checkpoint is corrupt, not merely old).
    pub fn manifest(&self) -> Result<Option<Manifest>> {
        let Some(text) = self.meta_value("manifest") else {
            return Ok(None);
        };
        let v = json::parse(text)
            .map_err(|e| anyhow!("embedded checkpoint manifest is corrupt: {e}"))?;
        Manifest::from_json(&v, Path::new("(embedded-in-checkpoint)"))
            .context("embedded checkpoint manifest is invalid")
            .map(Some)
    }

    pub fn step(&self) -> usize {
        self.meta_value("step").and_then(|s| s.parse().ok()).unwrap_or(0)
    }

    /// Tensors of one group ("param" | "m" | "v"), in stored order.
    pub fn group(&self, group: &str) -> Vec<Vec<f32>> {
        let prefix = format!("{group}/");
        self.tensors
            .iter()
            .filter(|(n, _, _)| n.starts_with(&prefix))
            .map(|(_, _, d)| d.clone())
            .collect()
    }

    /// One tensor by full name.
    pub fn tensor(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.tensors
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, s, d)| (s.as_slice(), d.as_slice()))
    }

    // -- I/O ----------------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut offset = 0u64;
        let mut entries = Vec::new();
        for (name, shape, data) in &self.tensors {
            entries.push(json::obj(vec![
                ("name", json::s(name)),
                ("shape", Value::Arr(shape.iter().map(|&d| json::num(d as f64)).collect())),
                ("offset", json::num(offset as f64)),
                ("len", json::num(data.len() as f64)),
            ]));
            offset += (data.len() * 4) as u64;
        }
        let header = json::obj(vec![
            (
                "meta",
                Value::Obj(self.meta.iter().map(|(k, v)| (k.clone(), json::s(v))).collect()),
            ),
            ("tensors", Value::Arr(entries)),
        ])
        .to_string();

        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        let mut w = std::io::BufWriter::new(f);
        for (_, _, data) in &self.tensors {
            // SAFETY-free little-endian write.
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for x in data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&bytes)?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not an HSM checkpoint", path.display());
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?).map_err(|e| anyhow!("{e}"))?;

        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;

        let meta = header
            .get("meta")
            .as_obj()
            .map(|m| {
                m.iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
                    .collect()
            })
            .unwrap_or_default();

        let mut tensors = Vec::new();
        for e in header.get("tensors").as_arr().unwrap_or(&[]) {
            let name = e.get("name").as_str().ok_or_else(|| anyhow!("tensor name"))?;
            let shape = e.get("shape").as_usize_vec().ok_or_else(|| anyhow!("tensor shape"))?;
            let offset = e.get("offset").as_usize().ok_or_else(|| anyhow!("tensor offset"))?;
            let len = e.get("len").as_usize().ok_or_else(|| anyhow!("tensor len"))?;
            let end = offset + len * 4;
            if end > payload.len() {
                bail!("checkpoint truncated: {name} needs {end} bytes, have {}", payload.len());
            }
            let data: Vec<f32> = payload[offset..end]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            tensors.push((name.to_string(), shape, data));
        }
        Ok(Checkpoint { meta, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LayerInfo;
    use crate::infer::weights;

    fn sample() -> (Manifest, Checkpoint) {
        let layers = vec![LayerInfo { kind: "ab".into(), heads: 1, shifts: vec![1], ffn: 4 }];
        let m = Manifest::synthetic("hsm_ab", layers, 4, 8, 16, 1);
        let params = weights::seeded_flat(&m, 3);
        let zeros: Vec<Vec<f32>> = m.params.iter().map(|p| vec![0.0; p.elems()]).collect();
        let ck = Checkpoint::from_training(&m, 123, params, zeros.clone(), zeros);
        (m, ck)
    }

    #[test]
    fn roundtrip() {
        let (m, ck) = sample();
        let path = std::env::temp_dir().join("hsm_ckpt_test.bin");
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(re.meta_value("variant"), Some("hsm_ab"));
        assert_eq!(re.step(), 123);
        assert_eq!(re.tensors.len(), 3 * m.params.len());
        assert_eq!(re.group("param").len(), m.params.len());
        let (shape, data) = re.tensor("param/tok_emb").unwrap();
        assert_eq!(shape, &[16, 4]);
        assert_eq!(data.len(), 64);
        assert_eq!(re.group("param")[0], data);
    }

    #[test]
    fn embedded_manifest_roundtrips() {
        let (m, ck) = sample();
        let path = std::env::temp_dir().join("hsm_ckpt_manifest.bin");
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        let m2 = re.manifest().unwrap().expect("manifest snapshot present");
        assert_eq!(m2.variant, m.variant);
        assert_eq!(m2.dim, m.dim);
        assert_eq!(m2.ctx, m.ctx);
        assert_eq!(m2.vocab, m.vocab);
        assert_eq!(m2.layers, m.layers);
        assert_eq!(m2.params, m.params);
        // The snapshot is enough to rebuild the native model's weights.
        let w = crate::infer::ModelWeights::from_checkpoint(&m2, &re).unwrap();
        assert_eq!(w.tok_emb.len(), m.vocab * m.dim);
    }

    #[test]
    fn pre_snapshot_checkpoint_has_no_manifest() {
        // Old checkpoints (no "manifest" meta) load fine and report None;
        // a corrupt snapshot is an error, not a silent None.
        let ck = Checkpoint::default();
        assert!(ck.manifest().unwrap().is_none());
        let mut bad = Checkpoint::default();
        bad.meta.push(("manifest".into(), "{not json".into()));
        assert!(bad.manifest().is_err());
    }

    #[test]
    fn rejects_non_checkpoint() {
        let path = std::env::temp_dir().join("hsm_ckpt_bogus.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn float_precision_exact() {
        let mut ck = Checkpoint::default();
        let vals = vec![f32::MIN_POSITIVE, -0.0, 1.5e-30, 3.14159265, f32::MAX];
        ck.tensors.push(("t".into(), vec![5], vals.clone()));
        let path = std::env::temp_dir().join("hsm_ckpt_prec.bin");
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        let (_, data) = re.tensor("t").unwrap();
        for (a, b) in vals.iter().zip(data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
