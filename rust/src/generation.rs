//! Autoregressive text generation (the paper's qualitative evaluation path).
//!
//! Everything generates through the [`Decoder`] trait: prefill the
//! prompt, then one `step` per sampled token.  Two decoder families plug
//! in:
//!
//! * [`crate::infer::NativeDecoder`] — the O(1)-state incremental engine
//!   (ring buffers / KV cache); N sessions share one weight set, which is
//!   what [`generate_batch`] uses for round-robin multi-prompt serving.
//! * [`WindowDecoder`] (here) — re-runs a full-context
//!   [`StepEngine::decode`] pass per token: the PJRT-artifact path, and
//!   the parity baseline for the native engine.  The fixed `[1, ctx]`
//!   window is padded with an end-of-text sentinel; causality of every
//!   mixer guarantees positions ≥ current are ignorable.
//!
//! Sampling (temperature / top-k, as described for the GPT output stage
//! in the paper's §2) is NaN-robust: ordering uses `f32::total_cmp` and
//! non-finite weights drop out of the draw, so a bad logit can never
//! panic the serving path.  Top-k selection is O(V) via
//! `select_nth_unstable_by` rather than a full sort.
//!
//! [`generate`] and [`generate_batch`] are thin wrappers over the
//! continuous-batching core in [`crate::serve`] (single-session and
//! fixed-membership modes respectively); production multi-user serving
//! goes through [`crate::serve::Scheduler`] directly.

use anyhow::{bail, Result};

use crate::config::Manifest;
use crate::infer::Decoder;
use crate::runtime::StepEngine;
use crate::serve;
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct SampleCfg {
    /// Softmax temperature; 0 = greedy argmax.
    pub temperature: f32,
    /// Keep only the k most likely tokens (0 = disabled).
    pub top_k: usize,
    pub max_new_tokens: usize,
    /// Base RNG seed; [`generate_batch`] derives per-sequence streams as
    /// `seed ^ sequence_index`.
    pub seed: u64,
    /// Stop at the end-of-text sentinel.
    pub stop_at_eot: bool,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { temperature: 0.8, top_k: 40, max_new_tokens: 64, seed: 0, stop_at_eot: true }
    }
}

/// Pick the next token from one row of logits.
///
/// NaN-safe: comparison uses `total_cmp` (never panics) and non-finite
/// softmax weights are treated as zero probability.
pub fn sample_logits(logits: &[f32], cfg: &SampleCfg, rng: &mut Rng) -> u32 {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k filter: partition the k largest to the front in O(V) — no
    // full O(V log V) sort of the vocabulary.  NaN ranks below every
    // finite logit (total_cmp alone would rank +NaN above +inf and let
    // garbage tokens displace real top-k candidates).
    let key = |i: u32| {
        let l = logits[i as usize];
        if l.is_nan() {
            f32::NEG_INFINITY
        } else {
            l
        }
    };
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        idx.select_nth_unstable_by(cfg.top_k - 1, |&a, &b| key(b).total_cmp(&key(a)));
        idx.truncate(cfg.top_k);
    }
    // Temperature softmax over the surviving set (numerically stable;
    // f32::max skips NaN so the shift stays finite if any logit is).
    let max = idx
        .iter()
        .map(|&i| logits[i as usize])
        .fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> = idx
        .iter()
        .map(|&i| {
            let w = ((logits[i as usize] - max) / cfg.temperature).exp();
            if w.is_finite() {
                w
            } else {
                0.0
            }
        })
        .collect();
    let total: f32 = weights.iter().sum();
    let mut u = rng.f32() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    *idx.last().unwrap()
}

/// Greedy argmax.  NaN logits lose every comparison (including at index
/// 0, via the −∞ starting value) and are never picked unless no logit
/// beats −∞ at all, in which case index 0 is returned.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_val = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if l > best_val {
            best = i;
            best_val = l;
        }
    }
    best as u32
}

/// Result of one generation call.
#[derive(Debug, Clone)]
pub struct Generation {
    pub prompt: String,
    pub completion: String,
    pub tokens_generated: usize,
    pub stopped_at_eot: bool,
}

/// [`Decoder`] over any full-context [`StepEngine::decode`] pass: keeps
/// the fixed `[1, ctx]` window (padded with an EOT sentinel, causally
/// invisible), re-decodes it every step, and serves the logit row at the
/// current position.  O(ctx) engine work per token — the baseline the
/// incremental engine is measured against, and the only decoder the PJRT
/// artifacts support.
pub struct WindowDecoder<'e, E: StepEngine + ?Sized> {
    engine: &'e mut E,
    pad: u32,
    window: Vec<i32>,
    len: usize,
    row: Vec<f32>,
}

impl<'e, E: StepEngine + ?Sized> WindowDecoder<'e, E> {
    /// `pad` fills unused window positions (conventionally the
    /// tokenizer's end-of-text id).
    pub fn new(engine: &'e mut E, pad: u32) -> Self {
        let (ctx, vocab) = (engine.manifest().ctx, engine.manifest().vocab);
        WindowDecoder { engine, pad, window: vec![pad as i32; ctx], len: 0, row: vec![0.0; vocab] }
    }

    fn push(&mut self, token: u32) -> Result<()> {
        let m = self.engine.manifest();
        if (token as usize) >= m.vocab {
            bail!("token {token} out of vocab {}", m.vocab);
        }
        if self.len >= m.ctx {
            bail!("context window ({}) exhausted — call reset()", m.ctx);
        }
        self.window[self.len] = token as i32;
        self.len += 1;
        Ok(())
    }
}

impl<E: StepEngine + ?Sized> Decoder for WindowDecoder<'_, E> {
    fn manifest(&self) -> &Manifest {
        self.engine.manifest()
    }

    /// Prompt tokens only move the cursor — no decode pass until the
    /// first `step` needs logits.
    fn prefill(&mut self, tokens: &[u32]) -> Result<()> {
        for &t in tokens {
            self.push(t)?;
        }
        Ok(())
    }

    fn step(&mut self, token: u32) -> Result<&[f32]> {
        self.push(token)?;
        let vocab = self.engine.manifest().vocab;
        let logits = self.engine.decode(&self.window)?;
        let pos = self.len - 1;
        self.row.copy_from_slice(&logits[pos * vocab..(pos + 1) * vocab]);
        Ok(&self.row)
    }

    fn reset(&mut self) {
        self.len = 0;
        self.window.fill(self.pad as i32);
    }

    fn position(&self) -> usize {
        self.len
    }
}

/// Shared prompt validation + encoding (also the serve scheduler's
/// admission check).
pub(crate) fn encode_prompt(
    dec_manifest: &Manifest,
    tok: &Tokenizer,
    prompt: &str,
) -> Result<Vec<u32>> {
    if tok.vocab_size() != dec_manifest.vocab {
        bail!(
            "tokenizer vocab {} does not match model vocab {}",
            tok.vocab_size(),
            dec_manifest.vocab
        );
    }
    let ids = tok.encode(prompt);
    if ids.is_empty() {
        bail!("prompt encodes to zero tokens");
    }
    if ids.len() >= dec_manifest.ctx {
        bail!(
            "prompt ({} tokens) must be shorter than ctx ({})",
            ids.len(),
            dec_manifest.ctx
        );
    }
    Ok(ids)
}

/// Convert a scheduler completion into the legacy [`Generation`] shape.
fn to_generation(c: serve::Completion) -> Generation {
    Generation {
        stopped_at_eot: c.finish == serve::FinishReason::Eot,
        prompt: c.prompt,
        completion: c.completion,
        tokens_generated: c.tokens_generated,
    }
}

/// Generate a completion for `prompt` through any [`Decoder`].
///
/// Thin wrapper over the serve core in single-session mode (one job, no
/// time slicing); the RNG stream is `cfg.seed` (request id 0), matching
/// [`generate_batch`]'s sequence-0 stream.
pub fn generate<D: Decoder + ?Sized>(
    dec: &mut D,
    tok: &Tokenizer,
    prompt: &str,
    cfg: &SampleCfg,
) -> Result<Generation> {
    let ids = encode_prompt(dec.manifest(), tok, prompt)?;
    let job = serve::Job {
        ix: 0,
        id: 0,
        budget: cfg.max_new_tokens,
        prompt: prompt.to_string(),
        ids,
        deadline: None,
        submitted: std::time::Instant::now(),
        sink: None,
    };
    let mut out = vec![None];
    serve::run_local(&mut [&mut *dec], tok, vec![job], cfg, 0, None, None, None, &mut out)?;
    Ok(to_generation(out.pop().unwrap().expect("single sequence completed")))
}

/// Convenience: generate through a full-context engine (the PJRT path)
/// by wrapping it in a [`WindowDecoder`].
pub fn generate_windowed<E: StepEngine + ?Sized>(
    engine: &mut E,
    tok: &Tokenizer,
    prompt: &str,
    cfg: &SampleCfg,
) -> Result<Generation> {
    let mut dec = WindowDecoder::new(engine, tok.eot);
    generate(&mut dec, tok, prompt, cfg)
}

/// Round-robin multi-prompt decoding: one decoder per prompt (for the
/// native engine, sessions sharing one `Arc<Model>` — the multi-user
/// serving shape), stepped breadth-first so every sequence advances one
/// token per round.
///
/// Thin wrapper over the serve core in fixed-membership mode: every
/// sequence is admitted up front (`decoders.len()` is the active-set
/// size) with a one-token quantum — the classic round-robin.  Sequence
/// `i` samples from an independent RNG stream seeded `cfg.seed ^ i`, so
/// results are identical whether prompts run batched, one at a time, or
/// through [`crate::serve::Scheduler`] with any thread count.
pub fn generate_batch<D: Decoder>(
    decoders: &mut [D],
    tok: &Tokenizer,
    prompts: &[&str],
    cfg: &SampleCfg,
) -> Result<Vec<Generation>> {
    if decoders.len() != prompts.len() {
        bail!(
            "{} decoders for {} prompts — supply one decoder per prompt",
            decoders.len(),
            prompts.len()
        );
    }
    let mut jobs = Vec::with_capacity(prompts.len());
    for (i, prompt) in prompts.iter().enumerate() {
        let ids = encode_prompt(decoders[i].manifest(), tok, prompt)?;
        jobs.push(serve::Job {
            ix: i,
            id: i as u64,
            budget: cfg.max_new_tokens,
            prompt: (*prompt).to_string(),
            ids,
            deadline: None,
            submitted: std::time::Instant::now(),
            sink: None,
        });
    }
    let mut out = vec![None; prompts.len()];
    serve::run_local(decoders, tok, jobs, cfg, 1, None, None, None, &mut out)?;
    Ok(out
        .into_iter()
        .map(|c| to_generation(c.expect("every sequence completed")))
        .collect())
}

/// The paper's Table 3 prompt suite (factual + reasoning prompts).
pub const TABLE3_PROMPTS: &[&str] = &[
    "Alice was so tired when she got home so she went",
    "Lily likes cats and dogs. She asked her mom for a dog and her mom says no, so instead she asked",
    "Once upon a time there was a pumpkin. It was a very special pumpkin, it could speak. It was sad because it couldn't move. Every day, it would say",
    "Jack and Lily liked to watch the moon at night. They noticed that the moon changed its shape every night. Sometimes the moon was big and round, and sometimes it was",
    "Jack wanted to read a book, so he went to",
    "Jack told Mary, 'If you give me your banana, I'll give you my apple'. Mary gave Jack her banana so",
    "On weekends Jack went to visit his grandmother whereas on weekdays he would go to school. Last weekend, when Jack was on his way to",
    "Lily and Ben were having an argument. Ben said that cake is much better than ice cream and Lily said that",
    "Jack's mother was not home, and his father was at home. When Jack came home, he said hello to",
    "Lily doesn't like swimming. When her father wants to take her to the swimming pool, she says",
    "Both Ben and Lily wanted cake. Father said that there was only one piece of cake left. They",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LayerInfo;
    use crate::coordinator::{test_manifest, MockEngine};
    use crate::corpus;
    use crate::infer::{weights, Model, ModelWeights};
    use crate::tokenizer::trainer as tok_trainer;

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(0);
        let cfg = SampleCfg { temperature: 0.0, ..Default::default() };
        assert_eq!(sample_logits(&[0.0, 9.0, 1.0], &cfg, &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(1);
        let cfg = SampleCfg { temperature: 1.0, top_k: 2, ..Default::default() };
        let logits = [10.0, 9.0, -50.0, -50.0];
        for _ in 0..100 {
            let t = sample_logits(&logits, &cfg, &mut rng);
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn top_k_survives_unsorted_input() {
        // select_nth partitions without sorting; the winners must still be
        // exactly the k largest wherever they sit.
        let mut rng = Rng::new(3);
        let cfg = SampleCfg { temperature: 0.5, top_k: 3, ..Default::default() };
        let logits = [-50.0, 8.0, -50.0, 9.0, -50.0, 10.0, -50.0];
        for _ in 0..200 {
            let t = sample_logits(&logits, &cfg, &mut rng);
            assert!(matches!(t, 1 | 3 | 5), "sampled outside top-3: {t}");
        }
    }

    #[test]
    fn nan_logits_never_panic() {
        let mut rng = Rng::new(4);
        let logits = [1.0, f32::NAN, 3.0, f32::NAN, 2.0];
        for top_k in [0, 2, 4] {
            let cfg = SampleCfg { temperature: 1.0, top_k, ..Default::default() };
            for _ in 0..100 {
                let t = sample_logits(&logits, &cfg, &mut rng);
                assert!((t as usize) < logits.len());
            }
        }
        // NaN never displaces finite candidates from the top-k set.
        let cfg = SampleCfg { temperature: 1.0, top_k: 2, ..Default::default() };
        let l2 = [f32::NAN, 10.0, 9.0, f32::NAN];
        for _ in 0..100 {
            let t = sample_logits(&l2, &cfg, &mut rng);
            assert!(t == 1 || t == 2, "NaN displaced a finite top-k candidate: {t}");
        }
        // Greedy ignores NaN everywhere — including index 0.
        assert_eq!(argmax(&logits), 2);
        assert_eq!(argmax(&[f32::NAN, 3.0, 5.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
    }

    #[test]
    fn temperature_zero_deterministic_high_temp_varied() {
        let logits: Vec<f32> = (0..20).map(|i| (i as f32) * 0.1).collect();
        let mut rng = Rng::new(2);
        let hot = SampleCfg { temperature: 5.0, top_k: 0, ..Default::default() };
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sample_logits(&logits, &hot, &mut rng));
        }
        assert!(seen.len() > 5, "high temperature should vary ({seen:?})");
    }

    #[test]
    fn generate_with_mock_engine() {
        let text = corpus::generate(5, 60);
        let tok = tok_trainer::train(&text, 300).unwrap();
        let mut eng = MockEngine::new(
            test_manifest("hsm_ab", 4, 32, tok.vocab_size()),
            1.8,
            0.01,
        );
        eng.init(0).unwrap();
        let cfg = SampleCfg { temperature: 0.0, max_new_tokens: 8, ..Default::default() };
        let g = generate_windowed(&mut eng, &tok, "Once upon a time", &cfg).unwrap();
        assert!(g.tokens_generated > 0);
        assert_eq!(g.prompt, "Once upon a time");
    }

    #[test]
    fn generate_rejects_vocab_mismatch() {
        let text = corpus::generate(5, 60);
        let tok = tok_trainer::train(&text, 300).unwrap();
        let mut eng = MockEngine::new(test_manifest("hsm_ab", 4, 32, 999), 1.8, 0.01);
        eng.init(0).unwrap();
        assert!(generate_windowed(&mut eng, &tok, "hi", &SampleCfg::default()).is_err());
    }

    fn native_model(tok_vocab: usize) -> std::sync::Arc<Model> {
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
        ];
        let m = crate::config::Manifest::synthetic("hsm_ab", layers, 8, 48, tok_vocab, 1);
        let flat = weights::seeded_flat(&m, 11);
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
    }

    #[test]
    fn generate_batch_matches_single_sessions() {
        let text = corpus::generate(9, 80);
        let tok = tok_trainer::train(&text, 300).unwrap();
        let model = native_model(tok.vocab_size());
        let cfg = SampleCfg { temperature: 0.8, top_k: 8, max_new_tokens: 6, seed: 5, ..Default::default() };
        let prompts = ["Once upon a time", "Lily likes cats"];

        // Batched: two sessions sharing one weight set, round-robin.
        let mut sessions = vec![model.session(), model.session()];
        let batched = generate_batch(&mut sessions, &tok, &prompts, &cfg).unwrap();
        assert_eq!(batched.len(), 2);

        // Sequential reference: per-sequence seed = cfg.seed ^ i.
        for (i, (prompt, b)) in prompts.iter().zip(&batched).enumerate() {
            let solo_cfg = SampleCfg { seed: cfg.seed ^ i as u64, ..cfg.clone() };
            let solo = generate(&mut model.session(), &tok, prompt, &solo_cfg).unwrap();
            assert_eq!(solo.completion, b.completion, "sequence {i} diverged under batching");
            assert_eq!(solo.tokens_generated, b.tokens_generated);
        }
    }

    #[test]
    fn generate_batch_rejects_mismatched_lengths() {
        let text = corpus::generate(9, 60);
        let tok = tok_trainer::train(&text, 300).unwrap();
        let model = native_model(tok.vocab_size());
        let mut sessions = vec![model.session()];
        assert!(generate_batch(&mut sessions, &tok, &["a", "b"], &SampleCfg::default()).is_err());
    }

    #[test]
    fn table3_prompt_suite_is_complete() {
        assert_eq!(TABLE3_PROMPTS.len(), 11);
    }
}
