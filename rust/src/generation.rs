//! Autoregressive text generation (the paper's qualitative evaluation path).
//!
//! Drives the `decode` artifact: encode the prompt, place it in the fixed
//! `[1, ctx]` window, run the full-context forward pass, sample the next
//! token from the logits at the current position (temperature / top-k, as
//! described for the GPT output stage in the paper's §2), append, repeat.
//!
//! Causality of every mixer guarantees positions ≥ current are ignorable,
//! so the window is simply padded with the end-of-text sentinel.

use anyhow::{bail, Result};

use crate::runtime::StepEngine;
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct SampleCfg {
    /// Softmax temperature; 0 = greedy argmax.
    pub temperature: f32,
    /// Keep only the k most likely tokens (0 = disabled).
    pub top_k: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Stop at the end-of-text sentinel.
    pub stop_at_eot: bool,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { temperature: 0.8, top_k: 40, max_new_tokens: 64, seed: 0, stop_at_eot: true }
    }
}

/// Pick the next token from one row of logits.
pub fn sample_logits(logits: &[f32], cfg: &SampleCfg, rng: &mut Rng) -> u32 {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k filter on (logit, index) pairs.
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| {
            logits[b as usize].partial_cmp(&logits[a as usize]).unwrap()
        });
        idx.truncate(cfg.top_k);
    }
    // Temperature softmax over the surviving set (numerically stable).
    let max = idx
        .iter()
        .map(|&i| logits[i as usize])
        .fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> = idx
        .iter()
        .map(|&i| ((logits[i as usize] - max) / cfg.temperature).exp())
        .collect();
    let total: f32 = weights.iter().sum();
    let mut u = rng.f32() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    *idx.last().unwrap()
}

/// Greedy argmax.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best as u32
}

/// Result of one generation call.
#[derive(Debug, Clone)]
pub struct Generation {
    pub prompt: String,
    pub completion: String,
    pub tokens_generated: usize,
    pub stopped_at_eot: bool,
}

/// Generate a completion for `prompt`.
pub fn generate<E: StepEngine + ?Sized>(
    engine: &mut E,
    tok: &Tokenizer,
    prompt: &str,
    cfg: &SampleCfg,
) -> Result<Generation> {
    let ctx = engine.manifest().ctx;
    let vocab = engine.manifest().vocab;
    if tok.vocab_size() != vocab {
        bail!(
            "tokenizer vocab {} does not match model vocab {vocab}",
            tok.vocab_size()
        );
    }
    let mut ids: Vec<u32> = tok.encode(prompt);
    if ids.is_empty() {
        bail!("prompt encodes to zero tokens");
    }
    if ids.len() >= ctx {
        bail!("prompt ({} tokens) must be shorter than ctx ({ctx})", ids.len());
    }
    let prompt_len = ids.len();
    let mut rng = Rng::new(cfg.seed);
    let mut stopped = false;

    while ids.len() < ctx && ids.len() - prompt_len < cfg.max_new_tokens {
        // Fixed-size window padded with EOT (causally invisible).
        let mut window: Vec<i32> = ids.iter().map(|&t| t as i32).collect();
        window.resize(ctx, tok.eot as i32);
        let logits = engine.decode(&window)?;
        let pos = ids.len() - 1;
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let next = sample_logits(row, cfg, &mut rng);
        if cfg.stop_at_eot && next == tok.eot {
            stopped = true;
            break;
        }
        ids.push(next);
    }

    let completion = tok.decode(&ids[prompt_len..]);
    Ok(Generation {
        prompt: prompt.to_string(),
        completion,
        tokens_generated: ids.len() - prompt_len,
        stopped_at_eot: stopped,
    })
}

/// The paper's Table 3 prompt suite (factual + reasoning prompts).
pub const TABLE3_PROMPTS: &[&str] = &[
    "Alice was so tired when she got home so she went",
    "Lily likes cats and dogs. She asked her mom for a dog and her mom says no, so instead she asked",
    "Once upon a time there was a pumpkin. It was a very special pumpkin, it could speak. It was sad because it couldn't move. Every day, it would say",
    "Jack and Lily liked to watch the moon at night. They noticed that the moon changed its shape every night. Sometimes the moon was big and round, and sometimes it was",
    "Jack wanted to read a book, so he went to",
    "Jack told Mary, 'If you give me your banana, I'll give you my apple'. Mary gave Jack her banana so",
    "On weekends Jack went to visit his grandmother whereas on weekdays he would go to school. Last weekend, when Jack was on his way to",
    "Lily and Ben were having an argument. Ben said that cake is much better than ice cream and Lily said that",
    "Jack's mother was not home, and his father was at home. When Jack came home, he said hello to",
    "Lily doesn't like swimming. When her father wants to take her to the swimming pool, she says",
    "Both Ben and Lily wanted cake. Father said that there was only one piece of cake left. They",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{test_manifest, MockEngine};
    use crate::corpus;
    use crate::tokenizer::trainer as tok_trainer;

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(0);
        let cfg = SampleCfg { temperature: 0.0, ..Default::default() };
        assert_eq!(sample_logits(&[0.0, 9.0, 1.0], &cfg, &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(1);
        let cfg = SampleCfg { temperature: 1.0, top_k: 2, ..Default::default() };
        let logits = [10.0, 9.0, -50.0, -50.0];
        for _ in 0..100 {
            let t = sample_logits(&logits, &cfg, &mut rng);
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn temperature_zero_deterministic_high_temp_varied() {
        let logits: Vec<f32> = (0..20).map(|i| (i as f32) * 0.1).collect();
        let mut rng = Rng::new(2);
        let hot = SampleCfg { temperature: 5.0, top_k: 0, ..Default::default() };
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sample_logits(&logits, &hot, &mut rng));
        }
        assert!(seen.len() > 5, "high temperature should vary ({seen:?})");
    }

    #[test]
    fn generate_with_mock_engine() {
        let text = corpus::generate(5, 60);
        let tok = tok_trainer::train(&text, 300).unwrap();
        let mut eng = MockEngine::new(
            test_manifest("hsm_ab", 4, 32, tok.vocab_size()),
            1.8,
            0.01,
        );
        eng.init(0).unwrap();
        let cfg = SampleCfg { temperature: 0.0, max_new_tokens: 8, ..Default::default() };
        let g = generate(&mut eng, &tok, "Once upon a time", &cfg).unwrap();
        assert!(g.tokens_generated > 0);
        assert_eq!(g.prompt, "Once upon a time");
    }

    #[test]
    fn generate_rejects_vocab_mismatch() {
        let text = corpus::generate(5, 60);
        let tok = tok_trainer::train(&text, 300).unwrap();
        let mut eng = MockEngine::new(test_manifest("hsm_ab", 4, 32, 999), 1.8, 0.01);
        eng.init(0).unwrap();
        assert!(generate(&mut eng, &tok, "hi", &SampleCfg::default()).is_err());
    }

    #[test]
    fn table3_prompt_suite_is_complete() {
        assert_eq!(TABLE3_PROMPTS.len(), 11);
    }
}
