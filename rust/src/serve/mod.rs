//! Continuous-batching serve subsystem: a threaded scheduler over
//! shared-weight decode sessions.
//!
//! The paper's O(1)-state incremental step (ring buffers instead of a
//! growing KV scan) makes per-token work cheap enough that serving
//! throughput is decided by *scheduling*, not math.  This module replaces
//! the fixed-membership round-robin loop that
//! [`crate::generation::generate_batch`] used to be with a real serving
//! core:
//!
//! * [`Request`] / [`Completion`] — the admission/finish lifecycle of one
//!   prompt, with a [`FinishReason`] (EOT, token cap, context eviction,
//!   or admission rejection).
//! * [`ServeCfg`] — admission control: at most `max_active` concurrent
//!   [`crate::infer::DecodeSession`]s, `threads` workers stepping them,
//!   `quantum`-token time slices.
//! * [`Scheduler`] — continuous batching over one `Arc<`[`Model`]`>`:
//!   the moment a sequence finishes, its session is recycled and the next
//!   pending request is admitted — **no barrier at batch end**.  With
//!   `threads > 1` a worker pool steps *disjoint* sessions in parallel
//!   (the model is immutable and `Send + Sync`; every mutable byte of a
//!   sequence lives in its own session).
//!
//! Two serving shapes share that core:
//!
//! * [`Scheduler`] / [`serve`] — **batch**: submit a `Vec<Request>`, get
//!   every [`Completion`] back when the batch drains.
//! * [`StreamScheduler`] — **resident**: worker threads stay up between
//!   requests; [`submit`](StreamScheduler::submit) at any time returns a
//!   [`TokenStream`] that yields [`TokenEvent`]s (one per sampled token,
//!   with the UTF-8-safe `text_delta` it unlocked, then a final `Done`
//!   carrying the [`Completion`]).  This is what the cross-process HTTP
//!   front-end in [`crate::server`] serves from.
//!
//! **Determinism invariant:** sequence `id` samples from an RNG stream
//! seeded `cfg.sample.seed ^ id`, and no per-sequence state is shared, so
//! completions are byte-identical whatever the admission order, quantum,
//! `max_active`, or thread count — and identical to decoding each request
//! alone in a fresh session.  Streaming never changes this: events are a
//! pure tap on the decode loop, and a slow (or vanished) consumer never
//! stalls or perturbs sampling.  `rust/tests/serve_parity.rs` and
//! `rust/tests/stream_parity.rs` pin this for every mixer kind.
//!
//! **Fairness beyond FIFO:** [`ServeCfg::max_queue_wait`] bounds how long
//! a request may sit queued for admission; past the budget it finishes as
//! [`FinishReason::TimedOut`] (never decoded) instead of waiting forever
//! behind a saturated active set.
//!
//! **Shared prefix cache:** HSM's O(1)-state decoding means the entire
//! session state after consuming a prompt head is a small
//! [`crate::infer::SessionState`] snapshot.  Both scheduler shapes keep a
//! [`PrefixCache`] (size via [`ServeCfg::prefix_cache_size`]): at
//! admission, a request restores the snapshot of its longest cached
//! token prefix and prefills only the uncached tail, then contributes
//! its own prompt-head snapshot back.  Restores are bit-exact, so a
//! cache hit can never change sampled text — only
//! [`Completion::cached_prefix_len`] and the time-to-first-token.
//!
//! **Cancel on disconnect:** a dropped [`TokenStream`] (in-process
//! consumer gone, or the HTTP peer closed its socket mid-stream) stops
//! that request's decoding at the next sampled token and frees its
//! session for the queue, finishing as [`FinishReason::Cancelled`] —
//! tokens are never burned on an unobservable stream.
//!
//! **Speculative decoding:** with [`ServeCfg::speculation`] set, each
//! sequence runs draft/verify rounds instead of single steps: a
//! [`Drafter`] ([`crate::infer::speculate`]) proposes a block, the full
//! model scores the whole block on the sequence's own forked state
//! (snapshot → score → restore to the accepted prefix, the machinery
//! PR 4 built), and each scored position is *sampled from the full
//! model's logits with the request's own RNG stream* — so the accepted
//! tokens, the correction token, and every byte that leaves the
//! scheduler are identical to plain decoding ([`advance_speculative`]
//! documents the argument; `rust/tests/spec_parity.rs` pins it).
//! Acceptance accounting lands on [`Completion::spec`] per request and
//! aggregates on the scheduler for `GET /healthz`.
//!
//! [`generate`](crate::generation::generate) (single-session) and
//! [`generate_batch`](crate::generation::generate_batch)
//! (fixed-membership) are thin wrappers over the same core
//! ([`run_local`]), so the pre-scheduler parity tests keep pinning the
//! decode semantics.

pub mod prefix;

pub use prefix::{PrefixCache, PrefixCacheStats};

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::generation::{encode_prompt, sample_logits, SampleCfg};
use crate::infer::speculate::{DraftCtx, Drafter, SpecCfg, SpecStats};
use crate::infer::{Decoder, Model, NativeDecoder, Precision, SessionState};
use crate::obs::{MetricsRegistry, ObsCfg, ObsRuntime, RequestEvent};
use crate::tokenizer::{StreamDecoder, Tokenizer};
use crate::util::rng::Rng;

/// One generation request, submitted to a [`Scheduler`].
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen id; the sequence's RNG stream is seeded
    /// `cfg.sample.seed ^ id`, so ids (not scheduling order) determine
    /// sampled text.  Duplicate ids get duplicate streams.
    pub id: u64,
    pub prompt: String,
    /// Per-request cap on generated tokens (None = `cfg.sample`'s cap).
    pub max_new_tokens: Option<usize>,
    /// Quota accounting key ([`ServeCfg::quota`]).  None = anonymous:
    /// the request bypasses per-user quotas.  Never affects sampled
    /// text — the RNG stream stays keyed by `id` alone.
    pub user: Option<String>,
    /// Per-request admission budget in milliseconds, overriding
    /// [`ServeCfg::max_queue_wait`]; also the ordering key under
    /// [`ServeCfg::edf`].  None = the cfg-wide budget.
    pub deadline_ms: Option<u64>,
}

impl Request {
    pub fn new(id: u64, prompt: &str) -> Self {
        Request {
            id,
            prompt: prompt.to_string(),
            max_new_tokens: None,
            user: None,
            deadline_ms: None,
        }
    }

    /// Builder-style quota key (see [`Request::user`]).
    pub fn with_user(mut self, user: &str) -> Self {
        self.user = Some(user.to_string());
        self
    }
}

/// Why a sequence left the active set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled the end-of-text sentinel.
    Eot,
    /// Hit the request's new-token cap.
    MaxTokens,
    /// Evicted: the context window filled before any other stop.
    CtxFull,
    /// Queued for admission longer than [`ServeCfg::max_queue_wait`];
    /// never decoded.
    TimedOut,
    /// The streaming consumer disconnected (its [`TokenStream`] was
    /// dropped, or the HTTP peer closed the socket); decoding stopped
    /// early and the session was freed.  `completion` holds the text
    /// sampled before the disconnect was noticed.
    Cancelled,
    /// Never admitted — the prompt failed validation (empty encoding,
    /// vocab mismatch, or longer than the context window).
    Rejected(String),
    /// Never admitted — refused by SLO backpressure (queue over
    /// [`ServeCfg::max_queue_depth`]) or a per-user quota
    /// ([`ServeCfg::quota`]).  Unlike [`FinishReason::Rejected`] this
    /// is a *capacity* disposition, not a client error: the same
    /// request retried later may succeed (HTTP answers 429 +
    /// `Retry-After`).
    Throttled(String),
}

impl FinishReason {
    /// Stable wire label (used by the HTTP API in [`crate::server`]).
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Eot => "eot",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::CtxFull => "ctx_full",
            FinishReason::TimedOut => "timed_out",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Rejected(_) => "rejected",
            FinishReason::Throttled(_) => "throttled",
        }
    }
}

/// The finished lifecycle of one [`Request`].
#[derive(Debug, Clone)]
pub struct Completion {
    pub request_id: u64,
    pub prompt: String,
    pub completion: String,
    pub tokens_generated: usize,
    /// Prompt tokens served from the shared [`PrefixCache`] instead of
    /// being prefilled (0 = cold prefill / caching disabled).  Purely
    /// informational: cached and cold decoding are byte-identical.
    pub cached_prefix_len: usize,
    /// Speculative-decoding acceptance accounting for this request
    /// (None when [`ServeCfg::speculation`] was off or the decoder
    /// could not fork).  Purely informational: speculative and plain
    /// decoding are byte-identical.
    pub spec: Option<SpecStats>,
    pub finish: FinishReason,
}

impl Completion {
    /// Compatibility accessor matching
    /// [`crate::generation::Generation::stopped_at_eot`].
    pub fn stopped_at_eot(&self) -> bool {
        self.finish == FinishReason::Eot
    }
}

/// Scheduler configuration: admission control + worker pool shape.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Concurrent-session cap: at most this many sequences hold decode
    /// state at once; the rest queue for admission.
    pub max_active: usize,
    /// Worker threads stepping sessions (1 = current thread, no spawn).
    pub threads: usize,
    /// Tokens a worker decodes on one sequence before rotating to the
    /// next ready one (0 = run each admitted sequence to completion).
    /// Pure scheduling knob — never changes sampled text.
    pub quantum: usize,
    /// Fairness-beyond-FIFO budget: a request still waiting for
    /// admission this long after submission finishes as
    /// [`FinishReason::TimedOut`] instead of queueing forever behind a
    /// saturated active set (None = wait indefinitely).  Checked when
    /// the request would be admitted; it never interrupts a sequence
    /// that is already decoding.
    pub max_queue_wait: Option<Duration>,
    /// Entry cap of the shared [`PrefixCache`] (0 = disabled).  Each
    /// entry is one [`crate::infer::SessionState`] snapshot at a
    /// prompt-head boundary; requests sharing a prompt head skip the
    /// cached part of their prefill.  Bit-exact — never changes sampled
    /// text, only TTFT and [`Completion::cached_prefix_len`].
    pub prefix_cache_size: usize,
    /// Speculative decoding (None = plain stepping).  Byte-exact: the
    /// drafter only decides how many full-model samples a verify round
    /// attempts, never what they are, so sampled text is identical with
    /// speculation on or off — only [`Completion::spec`] and the
    /// tokens-per-round economics change.
    pub speculation: Option<SpecCfg>,
    /// Sampling parameters shared by every request.
    pub sample: SampleCfg,
    /// The weight precision this scheduler expects to serve at
    /// ([`Precision::F32`] by default).  Precision is decided at model
    /// *load* time ([`Model::shared_with_precision`]); the cfg names it
    /// again so a serving stack wired for int8 fails loudly at
    /// construction ([`ServeCfg::validate_model`]) instead of silently
    /// decoding at the wrong precision after a bad reload.
    pub precision: Precision,
    /// Telemetry ([`crate::obs`]): counters + latency histograms on by
    /// default (overhead pinned ≤ 3% by `benches/observability.rs`;
    /// never changes sampled text).  [`ObsCfg::off`] disables every
    /// hook; [`ObsCfg::metrics`] shares a registry across schedulers;
    /// [`ObsCfg::request_log`] adds a JSON-lines lifecycle log.
    pub obs: ObsCfg,
    /// SLO backpressure for resident schedulers: once this many jobs
    /// are already queued, [`StreamScheduler::try_submit`] refuses with
    /// [`SubmitError::Throttled`] (HTTP answers 429 + `Retry-After`)
    /// instead of queueing without bound (0 = unbounded, the
    /// pre-backpressure behavior).  Pure admission control — never
    /// changes sampled text.
    pub max_queue_depth: usize,
    /// Per-user request/token quotas over a fixed window (None = off).
    /// Only requests carrying [`Request::user`] are accounted;
    /// anonymous requests bypass quotas.  An over-quota request is
    /// refused at admission ([`FinishReason::Throttled`] on the batch
    /// path, [`SubmitError::Throttled`] on the resident path).
    pub quota: Option<QuotaCfg>,
    /// Earliest-deadline-first ordering among *queued* jobs (false =
    /// FIFO).  Deadlines come from [`Request::deadline_ms`] or
    /// [`ServeCfg::max_queue_wait`]; jobs without one sort last.  Pure
    /// scheduling: per-request RNG streams mean admission order never
    /// changes sampled text — only who times out under saturation.
    pub edf: bool,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            max_active: 8,
            threads: 4,
            quantum: 16,
            max_queue_wait: None,
            prefix_cache_size: 32,
            speculation: None,
            sample: SampleCfg::default(),
            precision: Precision::F32,
            obs: ObsCfg::default(),
            max_queue_depth: 0,
            quota: None,
            edf: false,
        }
    }
}

/// Per-user admission quotas ([`ServeCfg::quota`]): fixed windows of at
/// most `max_requests` requests and `max_tokens` tokens per user.
/// Tokens are charged pessimistically at admission (prompt length +
/// generation budget), so a user cannot oversubscribe a window by
/// submitting before earlier requests finish.  Either cap can be 0 =
/// unlimited.
#[derive(Debug, Clone)]
pub struct QuotaCfg {
    /// Requests a user may admit per window (0 = unlimited).
    pub max_requests: u64,
    /// Tokens (prompt + budget) a user may admit per window (0 = unlimited).
    pub max_tokens: u64,
    /// Accounting window; usage resets when it elapses.
    pub window: Duration,
}

impl Default for QuotaCfg {
    fn default() -> Self {
        QuotaCfg { max_requests: 0, max_tokens: 0, window: Duration::from_secs(60) }
    }
}

impl QuotaCfg {
    pub fn validate(&self) -> Result<()> {
        if self.window.is_zero() {
            bail!("serve: quota window must be positive (a zero window can never admit anything)");
        }
        Ok(())
    }
}

/// Why admission refused a request ([`SubmitError::Throttled`], HTTP
/// 429).  Carries everything a client needs to back off sensibly.
#[derive(Debug, Clone)]
pub enum AdmissionError {
    /// The pending queue is at [`ServeCfg::max_queue_depth`].
    QueueFull { depth: usize, limit: usize, retry_after: Duration },
    /// The request's user is over a [`QuotaCfg`] cap this window.
    QuotaExceeded { user: String, what: &'static str, retry_after: Duration },
}

impl AdmissionError {
    /// Suggested client backoff — the HTTP front-end's `Retry-After`.
    pub fn retry_after(&self) -> Duration {
        match self {
            AdmissionError::QueueFull { retry_after, .. }
            | AdmissionError::QuotaExceeded { retry_after, .. } => *retry_after,
        }
    }

    /// Stable cause label for `hsm_requests_throttled_total{cause=...}`.
    pub fn cause(&self) -> &'static str {
        match self {
            AdmissionError::QueueFull { .. } => "queue_full",
            AdmissionError::QuotaExceeded { .. } => "quota",
        }
    }
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { depth, limit, .. } => {
                write!(f, "queue full ({depth} waiting, limit {limit})")
            }
            AdmissionError::QuotaExceeded { user, what, .. } => {
                write!(f, "user {user:?} is over its {what} quota this window")
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Typed error surface of [`StreamScheduler::try_submit`]:
/// backpressure/quota refusals (retryable, HTTP 429) are
/// distinguishable from a scheduler that cannot take work at all
/// (HTTP 503).
#[derive(Debug)]
pub enum SubmitError {
    /// Refused by admission control; retry after
    /// [`AdmissionError::retry_after`].
    Throttled(AdmissionError),
    /// The scheduler is shut down or a worker failed.
    Unavailable(anyhow::Error),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Throttled(adm) => write!(f, "throttled: {adm}"),
            SubmitError::Unavailable(e) => write!(f, "{e:#}"),
        }
    }
}

/// Per-user fixed-window usage ledger behind [`ServeCfg::quota`].
/// Shared by every submission path of one scheduler; the mutex is held
/// only for a map lookup + compare, never across decoding.
pub(crate) struct QuotaState {
    cfg: QuotaCfg,
    users: Mutex<HashMap<String, UserWindow>>,
}

struct UserWindow {
    window_start: Instant,
    requests: u64,
    tokens: u64,
}

impl QuotaState {
    pub(crate) fn new(cfg: QuotaCfg) -> Self {
        QuotaState { cfg, users: Mutex::new(HashMap::new()) }
    }

    /// Atomically charge one request + `tokens` tokens to `user`, or
    /// refuse without charging anything.  The refusal's `retry_after`
    /// is the time left in the user's current window.
    pub(crate) fn try_charge(&self, user: &str, tokens: u64) -> Result<(), AdmissionError> {
        let now = Instant::now();
        let mut users = self.users.lock().expect("quota lock poisoned");
        let w = users
            .entry(user.to_string())
            .or_insert(UserWindow { window_start: now, requests: 0, tokens: 0 });
        if now.duration_since(w.window_start) >= self.cfg.window {
            w.window_start = now;
            w.requests = 0;
            w.tokens = 0;
        }
        let retry_after = self
            .cfg
            .window
            .saturating_sub(now.duration_since(w.window_start))
            .max(Duration::from_secs(1));
        if self.cfg.max_requests > 0 && w.requests + 1 > self.cfg.max_requests {
            return Err(AdmissionError::QuotaExceeded {
                user: user.to_string(),
                what: "request",
                retry_after,
            });
        }
        if self.cfg.max_tokens > 0 && w.tokens + tokens > self.cfg.max_tokens {
            return Err(AdmissionError::QuotaExceeded {
                user: user.to_string(),
                what: "token",
                retry_after,
            });
        }
        w.requests += 1;
        w.tokens += tokens;
        Ok(())
    }
}

/// `Retry-After` estimate for a full queue: roughly how long until the
/// backlog drains one admission slot's worth, clamped to [1s, 60s].
fn queue_retry_after(depth: usize, max_active: usize) -> Duration {
    Duration::from_secs((depth / max_active.max(1)).clamp(1, 60) as u64)
}

impl ServeCfg {
    /// Construction-time validation shared by every scheduler shape: a
    /// zero `max_active` would admit nothing (every request queues
    /// forever) and zero `threads` would spawn no workers.
    pub fn validate(&self) -> Result<()> {
        if self.max_active == 0 {
            bail!("serve: max_active must be at least 1 (0 admits nothing — requests would queue forever)");
        }
        if self.threads == 0 {
            bail!("serve: threads must be at least 1 (0 spawns no workers — nothing would ever decode)");
        }
        if let Some(spec) = &self.speculation {
            spec.validate()?;
        }
        if let Some(quota) = &self.quota {
            quota.validate()?;
        }
        Ok(())
    }

    /// Cross-check against the model this scheduler will actually run:
    /// [`ServeCfg::precision`] must match what the model was loaded as.
    /// Called wherever a cfg meets its model ([`Scheduler::new`],
    /// [`serve`], [`StreamScheduler::start`]).
    pub fn validate_model(&self, model: &Model) -> Result<()> {
        if self.precision != model.precision() {
            bail!(
                "serve: cfg expects {} weights but the model was loaded as {}",
                self.precision.label(),
                model.precision().label()
            );
        }
        Ok(())
    }

    /// Validation for retained schedulers ([`Scheduler`],
    /// [`StreamScheduler`]): additionally requires a positive `quantum`.
    /// Run-to-completion slicing (`quantum == 0`) stays available through
    /// the one-shot [`serve`] call, but in a long-running scheduler it
    /// would let one unbounded request monopolize a session with no
    /// rotation — a degenerate loop for every stream queued behind it.
    pub fn validate_resident(&self) -> Result<()> {
        self.validate()?;
        if self.quantum == 0 {
            bail!(
                "serve: quantum must be at least 1 for a resident scheduler \
                 (0 = run-to-completion would let one request monopolize a session)"
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Streaming surface
// ---------------------------------------------------------------------------

/// One streaming event from a decoding request.
///
/// Concatenating every `text_delta` (all `Token`s, then the final
/// `Done`'s flush) is byte-identical to the finished
/// [`Completion::completion`] — pinned by `rust/tests/stream_parity.rs`.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// One sampled token and the text it unlocked.  `text_delta` may be
    /// empty while a multi-byte UTF-8 character is still incomplete
    /// (see [`crate::tokenizer::StreamDecoder`]).
    Token { request_id: u64, token: u32, text_delta: String },
    /// Terminal event: any bytes still buffered mid-character flush as
    /// `text_delta`, and `completion` carries the finished lifecycle.
    Done { text_delta: String, completion: Completion },
}

/// Receiving end of one request's event stream (from
/// [`StreamScheduler::submit`]).  Iterate it, or [`recv`](Self::recv) /
/// [`wait`](Self::wait) directly; the stream ends after the
/// [`TokenEvent::Done`] event (or early, with no `Done`, if the
/// scheduler failed).
pub struct TokenStream {
    request_id: u64,
    rx: Receiver<TokenEvent>,
}

impl TokenStream {
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    /// Block for the next event; `None` once the stream is over.
    pub fn recv(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Drain the stream, invoking `on_delta` for every text fragment in
    /// order; returns the final [`Completion`], or `None` if the
    /// scheduler dropped the request without finishing it (worker
    /// failure or panic).
    pub fn wait<F: FnMut(&str)>(self, mut on_delta: F) -> Option<Completion> {
        while let Ok(ev) = self.rx.recv() {
            match ev {
                TokenEvent::Token { text_delta, .. } => on_delta(&text_delta),
                TokenEvent::Done { text_delta, completion } => {
                    on_delta(&text_delta);
                    return Some(completion);
                }
            }
        }
        None
    }
}

impl Iterator for TokenStream {
    type Item = TokenEvent;

    fn next(&mut self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }
}

/// Continuous-batching scheduler bound to one shared-weight [`Model`].
///
/// Holding a `Scheduler` is the multi-user serving shape: construct it
/// once and call [`serve`](Scheduler::serve) per request batch; sessions
/// are created lazily per call (weights are never copied — they live in
/// the `Arc`).
pub struct Scheduler {
    model: Arc<Model>,
    cfg: ServeCfg,
    /// Shared prompt-head snapshot cache; persists across
    /// [`serve`](Scheduler::serve) calls, so requests in *later* batches
    /// still hit the heads earlier batches paid for.
    cache: Option<Arc<PrefixCache>>,
    /// Telemetry runtime (None with [`ObsCfg::off`]); persists across
    /// calls so histograms aggregate the scheduler's whole lifetime.
    obs: Option<Arc<ObsRuntime>>,
    /// Per-user quota ledger (None with [`ServeCfg::quota`] off);
    /// persists across [`serve`](Scheduler::serve) calls so windows
    /// span batches.
    quota: Option<QuotaState>,
}

impl Scheduler {
    /// Validates `cfg` at construction ([`ServeCfg::validate_resident`])
    /// so a zero `threads`/`max_active`/`quantum` fails here with a clear
    /// error instead of hanging or degenerating at serve time.
    pub fn new(model: Arc<Model>, cfg: ServeCfg) -> Result<Self> {
        cfg.validate_resident()?;
        cfg.validate_model(&model)?;
        let obs = ObsRuntime::from_cfg(&cfg.obs);
        if let Some(o) = &obs {
            o.registry
                .set_model_resident(model.precision().label(), model.resident_weight_bytes() as u64);
        }
        let cache = (cfg.prefix_cache_size > 0).then(|| {
            Arc::new(match &obs {
                // Cache events feed the metrics registry directly, so
                // /healthz and /metrics read one set of counters.
                Some(o) => PrefixCache::with_counters(
                    model.fingerprint(),
                    cfg.prefix_cache_size,
                    o.registry.cache_counters(),
                ),
                None => PrefixCache::new(model.fingerprint(), cfg.prefix_cache_size),
            })
        });
        let quota = cfg.quota.clone().map(QuotaState::new);
        Ok(Scheduler { model, cfg, cache, obs, quota })
    }

    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.cfg
    }

    /// The shared prefix cache (None when disabled) — stats feed
    /// monitoring (`GET /healthz` uses the [`StreamScheduler`] twin).
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.cache.as_ref()
    }

    /// The metrics registry this scheduler records into (None with
    /// [`ObsCfg::off`]).  Render it with
    /// [`MetricsRegistry::render_prometheus`].
    pub fn metrics(&self) -> Option<&Arc<MetricsRegistry>> {
        self.obs.as_ref().map(|o| &o.registry)
    }

    /// Serve a batch of requests to completion; results come back in
    /// request order.  Invalid prompts are rejected per-request
    /// ([`FinishReason::Rejected`]) without failing the batch; engine
    /// errors (a model/session fault) abort the whole call.
    pub fn serve(&self, tok: &Tokenizer, requests: Vec<Request>) -> Result<Vec<Completion>> {
        serve_with_cache(
            &self.model,
            tok,
            requests,
            &self.cfg,
            self.cache.as_deref(),
            self.obs.as_deref(),
            self.quota.as_ref(),
        )
    }
}

/// One-shot convenience for [`Scheduler::serve`].  The prefix cache (if
/// [`ServeCfg::prefix_cache_size`] > 0) lives for this call only —
/// shared heads *within* the batch still skip re-prefilling; hold a
/// [`Scheduler`] or [`StreamScheduler`] to share across calls.
pub fn serve(
    model: &Arc<Model>,
    tok: &Tokenizer,
    requests: Vec<Request>,
    cfg: &ServeCfg,
) -> Result<Vec<Completion>> {
    cfg.validate_model(model)?;
    let obs = ObsRuntime::from_cfg(&cfg.obs);
    if let Some(o) = &obs {
        o.registry
            .set_model_resident(model.precision().label(), model.resident_weight_bytes() as u64);
    }
    let cache = (cfg.prefix_cache_size > 0).then(|| match &obs {
        Some(o) => PrefixCache::with_counters(
            model.fingerprint(),
            cfg.prefix_cache_size,
            o.registry.cache_counters(),
        ),
        None => PrefixCache::new(model.fingerprint(), cfg.prefix_cache_size),
    });
    let quota = cfg.quota.clone().map(QuotaState::new);
    serve_with_cache(model, tok, requests, cfg, cache.as_ref(), obs.as_deref(), quota.as_ref())
}

/// The batch core behind [`Scheduler::serve`] and [`serve`].
#[allow(clippy::too_many_arguments)]
fn serve_with_cache(
    model: &Arc<Model>,
    tok: &Tokenizer,
    requests: Vec<Request>,
    cfg: &ServeCfg,
    cache: Option<&PrefixCache>,
    obs: Option<&ObsRuntime>,
    quota: Option<&QuotaState>,
) -> Result<Vec<Completion>> {
    cfg.validate()?;

    // Validate at admission: a bad prompt becomes a Rejected completion
    // (one user's malformed request must not fail everyone else's), and
    // an over-quota user's request a Throttled one.
    let submitted = Instant::now();
    let mut out: Vec<Option<Completion>> = vec![None; requests.len()];
    let mut jobs: Vec<Job> = Vec::with_capacity(requests.len());
    for (ix, req) in requests.into_iter().enumerate() {
        let unadmitted = |finish: FinishReason| Completion {
            request_id: req.id,
            prompt: req.prompt.clone(),
            completion: String::new(),
            tokens_generated: 0,
            cached_prefix_len: 0,
            spec: None,
            finish,
        };
        let ids = match encode_prompt(&model.manifest, tok, &req.prompt) {
            Ok(ids) => ids,
            Err(e) => {
                note_rejected(obs, req.id, submitted);
                out[ix] = Some(unadmitted(FinishReason::Rejected(format!("{e:#}"))));
                continue;
            }
        };
        let budget = req.max_new_tokens.unwrap_or(cfg.sample.max_new_tokens);
        if let (Some(q), Some(user)) = (quota, req.user.as_deref()) {
            let tokens = (ids.len() + budget) as u64;
            if let Err(adm) = q.try_charge(user, tokens) {
                note_throttled(obs, req.id, submitted, &adm);
                out[ix] = Some(unadmitted(FinishReason::Throttled(adm.to_string())));
                continue;
            }
            if let Some(o) = obs {
                if o.counters {
                    o.registry.add_quota_tokens(tokens);
                }
            }
        }
        let deadline = req
            .deadline_ms
            .map(|ms| submitted + Duration::from_millis(ms))
            .or_else(|| cfg.max_queue_wait.map(|d| submitted + d));
        jobs.push(Job {
            ix,
            id: req.id,
            budget,
            prompt: req.prompt,
            ids,
            deadline,
            submitted,
            sink: None,
        });
    }
    if cfg.edf {
        // Earliest deadline first among admitted jobs; the stable sort
        // keeps submission order for ties and deadline-free jobs.
        jobs.sort_by(|a, b| match (a.deadline, b.deadline) {
            (Some(x), Some(y)) => x.cmp(&y),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => std::cmp::Ordering::Equal,
        });
    }

    if !jobs.is_empty() {
        let n_sessions = cfg.max_active.min(jobs.len());
        if cfg.threads == 1 {
            let mut sessions: Vec<NativeDecoder> =
                (0..n_sessions).map(|_| model.session()).collect();
            run_local(
                &mut sessions,
                tok,
                jobs,
                &cfg.sample,
                cfg.quantum,
                cache,
                cfg.speculation.as_ref(),
                obs,
                &mut out,
            )?;
        } else {
            run_parallel(model, tok, jobs, cfg, n_sessions, cache, obs, &mut out)?;
        }
    }

    Ok(out
        .into_iter()
        .map(|c| c.expect("scheduler drained every request"))
        .collect())
}

// ---------------------------------------------------------------------------
// Core: per-sequence state machine, shared by the local and threaded drivers
// ---------------------------------------------------------------------------

/// An admitted-but-not-started request: slot index, validated prompt ids
/// and the per-request token budget.
pub(crate) struct Job {
    /// Output slot (input order).
    pub(crate) ix: usize,
    pub(crate) id: u64,
    pub(crate) budget: usize,
    pub(crate) prompt: String,
    pub(crate) ids: Vec<u32>,
    /// Admission deadline (from [`ServeCfg::max_queue_wait`]); a job
    /// popped past it finishes as [`FinishReason::TimedOut`] without
    /// ever touching a decoder.
    pub(crate) deadline: Option<Instant>,
    /// Intake time — queue-wait and end-to-end latency baseline.
    pub(crate) submitted: Instant,
    /// Streaming event sink (None on the batch path).
    pub(crate) sink: Option<Sender<TokenEvent>>,
}

/// Per-sequence streaming tap: the event channel plus the incremental
/// detokenizer feeding its `text_delta`s.  A vanished consumer (send
/// error) marks the tap dead; decoding continues unchanged so the
/// determinism invariant is untouched.
struct StreamOut {
    tx: Sender<TokenEvent>,
    sd: StreamDecoder,
    dead: bool,
}

impl StreamOut {
    fn emit(&mut self, ev: TokenEvent) {
        if !self.dead && self.tx.send(ev).is_err() {
            self.dead = true;
        }
    }
}

/// Per-sequence speculative-decoding state: the drafter plus reusable
/// round buffers (draft block, scored token block, logit rows,
/// per-position snapshots for the sequential path) and the request's
/// acceptance accounting.
struct SpecRunner {
    drafter: Box<dyn Drafter>,
    /// Drafter label for the request log (e.g. `ngram:3`).
    drafter_label: String,
    draft_len: usize,
    /// Score rounds with one fused `step_batch`/`rewind_batch` pass
    /// ([`SpecCfg::fused`] ∧ the decoder supports it); otherwise step +
    /// snapshot per position.
    fused: bool,
    stats: SpecStats,
    draft: Vec<u32>,
    /// Fused path: the scored block `[last, d_1..d_k]`.
    block: Vec<u32>,
    logits: Vec<Vec<f32>>,
    /// Sequential path only: the per-position restore targets.
    snaps: Vec<SessionState>,
}

/// One in-flight sequence.  Everything mutable is per-request (decoder
/// state, token buffer, RNG stream, stream tap, drafter), which is the
/// whole determinism argument: any interleaving of disjoint `Active`s
/// produces identical text.
struct Active<D> {
    dec: D,
    ix: usize,
    id: u64,
    prompt: String,
    ids: Vec<u32>,
    prompt_len: usize,
    last: u32,
    rng: Rng,
    budget: usize,
    /// Prompt tokens restored from the prefix cache at admission.
    cached_prefix_len: usize,
    /// Speculative decoding (None = plain stepping; also None when the
    /// decoder cannot snapshot/fork, e.g. the window baseline).
    spec: Option<SpecRunner>,
    stream: Option<StreamOut>,
    /// Intake time (copied from [`Job::submitted`]) — e2e latency base.
    submitted: Instant,
    /// When the previous token was emitted; None until the first, so
    /// [`note_token`] can split TTFT from inter-token latency.  Only
    /// written when telemetry timing is on.
    last_token_at: Option<Instant>,
}

/// Bind a decoder to a job: reset, prefill all but the last prompt token
/// (its logits come from the first `step`), seed the sequence RNG.
///
/// With a [`PrefixCache`], the prompt head (`ids[..len-1]`) first tries
/// a longest-prefix snapshot restore, prefilling only the uncached tail
/// — bit-exact, so admission order and cache contents can never change
/// sampled text.  Whatever this request prefills beyond the hit is
/// published back as snapshots at [`prefix::SNAPSHOT_STRIDE`]-aligned
/// boundaries (so requests sharing only a prompt *head* still hit the
/// last common boundary) plus one at its full head (so duplicate
/// prompts skip the whole prefill).
fn admit<D: Decoder>(
    mut dec: D,
    job: Job,
    cfg: &SampleCfg,
    cache: Option<&PrefixCache>,
    spec: Option<&SpecCfg>,
    obs: Option<&ObsRuntime>,
) -> Result<Active<D>> {
    let prompt_len = job.ids.len();
    if let Some(o) = obs {
        if o.counters {
            o.registry.inc_admitted();
            o.registry.add_prompt_tokens(prompt_len as u64);
        }
        if let Some(now) = o.now() {
            let wait = now.duration_since(job.submitted);
            o.registry.record_queue_wait(wait);
            o.emit(RequestEvent::Admitted {
                request_id: job.id,
                prompt_tokens: prompt_len as u64,
                queue_wait_ms: wait.as_secs_f64() * 1e3,
            });
        }
    }
    let prefill_t0 = obs.and_then(|o| o.now());
    let head = &job.ids[..prompt_len - 1];
    dec.reset();
    if let Some(o) = obs {
        if o.timing && o.stage_sample_every > 0 {
            dec.attach_stage_obs(&o.registry, o.stage_sample_every);
        }
    }
    let mut cached_prefix_len = 0;
    match cache {
        Some(cache) if !head.is_empty() => {
            let fp = dec.fingerprint();
            if let Some((len, state)) = cache.lookup(fp, head) {
                // A decoder that cannot restore (no snapshot support)
                // just cold-prefills; the lookup already counted a hit,
                // which is fine — the cache exists for native sessions.
                match dec.restore(&state) {
                    Ok(()) => cached_prefix_len = len,
                    Err(_) => dec.reset(),
                }
            }
            // Prefill the uncached tail in stride-aligned chunks,
            // snapshotting at each boundary.  Chunking a prefill is a
            // pure re-grouping of the same per-token steps, so numerics
            // are untouched.
            let mut at = cached_prefix_len;
            while at < head.len() {
                let next = ((at / prefix::SNAPSHOT_STRIDE) + 1) * prefix::SNAPSHOT_STRIDE;
                let next = next.min(head.len());
                dec.prefill(&head[at..next])?;
                at = next;
                if let Some(snap) = dec.snapshot() {
                    cache.insert(fp, &head[..at], snap);
                }
            }
        }
        _ => dec.prefill(head)?,
    }
    // Speculation needs snapshot/restore (the verify loop's rewind) and
    // a drafter; a decoder offering neither just decodes plainly —
    // byte-identical either way, so the fallback is invisible.
    let spec = spec
        .filter(|_| dec.supports_snapshot())
        .and_then(|sc| {
            dec.drafter(&sc.drafter).map(|drafter| SpecRunner {
                drafter,
                drafter_label: sc.drafter.label().to_string(),
                draft_len: sc.draft_len,
                fused: sc.fused && dec.supports_step_batch(),
                stats: SpecStats::default(),
                draft: Vec::new(),
                block: Vec::new(),
                logits: Vec::new(),
                snaps: Vec::new(),
            })
        });
    if let (Some(o), Some(t0)) = (obs, prefill_t0) {
        o.emit(RequestEvent::Started {
            request_id: job.id,
            cached_prefix_len: cached_prefix_len as u64,
            prefill_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
    }
    Ok(Active {
        last: job.ids[prompt_len - 1],
        dec,
        ix: job.ix,
        id: job.id,
        prompt: job.prompt,
        ids: job.ids,
        prompt_len,
        rng: Rng::new(cfg.seed ^ job.id),
        budget: job.budget,
        cached_prefix_len,
        spec,
        stream: job.sink.map(|tx| StreamOut { tx, sd: StreamDecoder::new(), dead: false }),
        submitted: job.submitted,
        last_token_at: None,
    })
}

/// Telemetry for a request rejected at intake (bad prompt): it never
/// touches a decoder, so it finishes here with zero tokens and no
/// model/drafter labels.
fn note_rejected(obs: Option<&ObsRuntime>, id: u64, submitted: Instant) {
    let Some(o) = obs else { return };
    if o.counters {
        o.registry.inc_finished("rejected");
    }
    if let Some(now) = o.now() {
        let e2e = now.duration_since(submitted);
        o.registry.record_e2e(e2e);
        o.emit(RequestEvent::Finished {
            request_id: id,
            finish: "rejected".into(),
            tokens_generated: 0,
            e2e_ms: e2e.as_secs_f64() * 1e3,
            mixer: "-".into(),
            precision: "-".into(),
            drafter: None,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            cached_prefix_len: 0,
        });
    }
}

/// Telemetry for a request refused by admission control (queue depth or
/// quota): like a rejection it never touches a decoder, but it counts
/// under its own `throttled` families so capacity refusals are
/// distinguishable from client errors on `/metrics`.
fn note_throttled(obs: Option<&ObsRuntime>, id: u64, submitted: Instant, err: &AdmissionError) {
    let Some(o) = obs else { return };
    if o.counters {
        o.registry.inc_throttled(err.cause());
        o.registry.inc_finished("throttled");
    }
    if let Some(now) = o.now() {
        let e2e = now.duration_since(submitted);
        o.registry.record_e2e(e2e);
        o.emit(RequestEvent::Finished {
            request_id: id,
            finish: "throttled".into(),
            tokens_generated: 0,
            e2e_ms: e2e.as_secs_f64() * 1e3,
            mixer: "-".into(),
            precision: "-".into(),
            drafter: None,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            cached_prefix_len: 0,
        });
    }
}

/// Has this queued job outlived its admission budget?
fn expired(job: &Job) -> bool {
    job.deadline.is_some_and(|d| Instant::now() > d)
}

/// Reap every expired job *anywhere* in the pending queue (not just the
/// front), delivering each TimedOut completion to `emit` (batch slots)
/// or its stream sink.  Called on every submit and every worker
/// scheduling pass, so under full saturation a queued request learns it
/// timed out within one scheduling quantum instead of whenever it
/// happens to reach the queue head — the front-only check let a stale
/// job hide behind a live one arbitrarily long.
fn reap_expired_queue<F: FnMut(usize, Completion)>(
    pending: &mut VecDeque<Job>,
    obs: Option<&ObsRuntime>,
    mut emit: F,
) {
    let mut i = 0;
    while i < pending.len() {
        if expired(&pending[i]) {
            let job = pending.remove(i).expect("reap index in bounds");
            if let Some((ix, completion)) = expire(job, obs) {
                emit(ix, completion);
            }
        } else {
            i += 1;
        }
    }
}

/// Queue insertion honoring [`ServeCfg::edf`]: earliest deadline first
/// (no deadline sorts last), FIFO among equals — the scan inserts
/// strictly before the first *later* deadline, so equal deadlines keep
/// submission order.
fn enqueue(pending: &mut VecDeque<Job>, job: Job, edf: bool) {
    if !edf {
        pending.push_back(job);
        return;
    }
    let pos = pending.iter().position(|q| match (q.deadline, job.deadline) {
        (None, Some(_)) => true,
        (Some(a), Some(b)) => a > b,
        _ => false,
    });
    match pos {
        Some(p) => pending.insert(p, job),
        None => pending.push_back(job),
    }
}

/// Finish a queued job as TimedOut without decoding.  Streaming jobs
/// deliver the completion through their sink (returns None); batch jobs
/// hand it back for the output slot.
fn expire(job: Job, obs: Option<&ObsRuntime>) -> Option<(usize, Completion)> {
    if let Some(o) = obs {
        if o.counters {
            o.registry.inc_finished("timed_out");
        }
        if let Some(now) = o.now() {
            let e2e = now.duration_since(job.submitted);
            o.registry.record_e2e(e2e);
            o.emit(RequestEvent::Finished {
                request_id: job.id,
                finish: "timed_out".into(),
                tokens_generated: 0,
                e2e_ms: e2e.as_secs_f64() * 1e3,
                mixer: "-".into(),
                precision: "-".into(),
                drafter: None,
                spec_rounds: 0,
                spec_drafted: 0,
                spec_accepted: 0,
                cached_prefix_len: 0,
            });
        }
    }
    let Job { ix, id, prompt, sink, .. } = job;
    let completion = Completion {
        request_id: id,
        prompt,
        completion: String::new(),
        tokens_generated: 0,
        cached_prefix_len: 0,
        spec: None,
        finish: FinishReason::TimedOut,
    };
    match sink {
        Some(tx) => {
            let _ = tx.send(TokenEvent::Done {
                text_delta: String::new(),
                completion,
            });
            None
        }
        None => Some((ix, completion)),
    }
}

/// Telemetry tap after each emitted token: a generated-token count
/// bump, then (only when timing or a request log is on) one clock read
/// that feeds either TTFT (first token) or the inter-token latency
/// histogram.  With telemetry off the caller skips this entirely, so
/// the decode loop stays clock-free and allocation-free.
fn note_token(id: u64, submitted: Instant, last_token_at: &mut Option<Instant>, obs: &ObsRuntime) {
    if obs.counters {
        obs.registry.add_tokens_generated(1);
    }
    let Some(now) = obs.now() else { return };
    match *last_token_at {
        None => {
            let ttft = now.duration_since(submitted);
            obs.registry.record_ttft(ttft);
            obs.emit(RequestEvent::FirstToken {
                request_id: id,
                ttft_ms: ttft.as_secs_f64() * 1e3,
            });
        }
        Some(prev) => obs.registry.record_token_latency(now.duration_since(prev)),
    }
    *last_token_at = Some(now);
}

/// Decode up to `quantum` tokens (0 = until finished).  Returns
/// `Some(reason)` when the sequence is done, `None` when its time slice
/// expired.  The stop conditions and sampling order mirror the original
/// `generate` loop exactly, so wrappers stay byte-compatible.
fn advance<D: Decoder>(
    seq: &mut Active<D>,
    tok: &Tokenizer,
    cfg: &SampleCfg,
    quantum: usize,
    obs: Option<&ObsRuntime>,
) -> Result<Option<FinishReason>> {
    if seq.spec.is_some() {
        return advance_speculative(seq, tok, cfg, quantum, obs);
    }
    let ctx = seq.dec.manifest().ctx;
    let mut sliced = 0usize;
    loop {
        if seq.ids.len() >= ctx {
            return Ok(Some(FinishReason::CtxFull));
        }
        if seq.ids.len() - seq.prompt_len >= seq.budget {
            return Ok(Some(FinishReason::MaxTokens));
        }
        let logits = seq.dec.step(seq.last)?;
        let next = sample_logits(logits, cfg, &mut seq.rng);
        if cfg.stop_at_eot && next == tok.eot {
            return Ok(Some(FinishReason::Eot));
        }
        seq.ids.push(next);
        seq.last = next;
        if let Some(o) = obs {
            note_token(seq.id, seq.submitted, &mut seq.last_token_at, o);
        }
        if let Some(out) = seq.stream.as_mut() {
            let text_delta = out.sd.push(tok, next);
            out.emit(TokenEvent::Token { request_id: seq.id, token: next, text_delta });
            // Cancel on disconnect: a dead sink means nobody can ever
            // observe this stream — stop decoding and free the session
            // instead of finishing unobserved.  Purely per-sequence, so
            // siblings' sampled text is untouched.
            if out.dead {
                return Ok(Some(FinishReason::Cancelled));
            }
        }
        sliced += 1;
        if quantum > 0 && sliced >= quantum {
            return Ok(None);
        }
    }
}

/// [`advance`], speculatively: draft/verify rounds instead of single
/// steps.  Byte-exactness argument, inductively per round:
///
/// * The full model scores the whole block `[last, d_1, .., d_k]` on
///   the sequence's own decoder — the logit row at position i is
///   conditioned on `last, d_1..d_i`.  Fused path: one multi-row
///   `step_batch` whose rows are bit-identical to sequential steps by
///   construction.  Sequential path (decoders without batch support,
///   or [`SpecCfg::fused`] off): one step + snapshot per position.
/// * The accept pass samples each scored row **with the request's RNG
///   stream, in emission order** ([`sample_logits`], exactly one draw
///   per emitted token — the same consumption plain decoding makes).
///   Along the accepted prefix `d_1..d_i` equal the previously emitted
///   tokens, so each row is bit-identical to the row plain decoding
///   would have produced (forked decode is bit-exact, PR 4), and so is
///   every sample.  The first non-matching sample is *itself* the correct
///   full-model token (its row conditions only on accepted tokens), so
///   it is emitted as the round's correction and the rest of the draft
///   is discarded.
/// * The decoder then rewinds (snapshot restore) to the state whose
///   consumed tokens are exactly the emitted history — wasted draft
///   suffix compute never contaminates state.
///
/// With a deterministic (point-mass) drafter this *is* exact rejection
/// sampling: the target-distribution sample either equals the proposal
/// (accept) or replaces it (reject + resample), so the output
/// distribution — and here, with the shared RNG stream, the byte
/// stream — is unchanged.  Greedy (temperature 0) is the classic
/// draft-then-argmax-verify special case.
///
/// Stop conditions (ctx, budget, EOT, cancel) fire at the same token
/// boundaries as plain decoding; the quantum check runs per round, so
/// a slice may overshoot by up to the block length — pure scheduling,
/// which never changes text.
///
/// **Cost shape:** the scoring pass always spends k+1 full-model
/// positions, so a rejected suffix is wasted work — but scoring the
/// whole block up front is exactly the shape that fuses: the fused
/// path scores all k+1 positions in **one `step_batch` pass per
/// round**, streaming each weight matrix through cache once for the
/// block and replacing the per-position snapshot clones (O(pos · D)
/// each for attention layers) with a single `rewind_batch`.
/// `benches/speculative.rs` quantifies the fused-vs-sequential trade
/// on the same workloads, byte parity asserted.
fn advance_speculative<D: Decoder>(
    seq: &mut Active<D>,
    tok: &Tokenizer,
    cfg: &SampleCfg,
    quantum: usize,
    obs: Option<&ObsRuntime>,
) -> Result<Option<FinishReason>> {
    let ctx = seq.dec.manifest().ctx;
    let mut sliced = 0usize;
    loop {
        if seq.ids.len() >= ctx {
            return Ok(Some(FinishReason::CtxFull));
        }
        let generated = seq.ids.len() - seq.prompt_len;
        if generated >= seq.budget {
            return Ok(Some(FinishReason::MaxTokens));
        }
        let round_t0 = obs.and_then(|o| o.now());
        let spec = seq.spec.as_mut().expect("speculative advance without a runner");
        // Block sizing: a round emits at most k+1 tokens, so k ≤
        // budget-remaining − 1 wastes nothing on unreachable drafts; and
        // the scoring pass consumes k+1 tokens from position ids.len()−1,
        // so k ≤ ctx − ids.len() keeps it inside the context window.
        let remaining = seq.budget - generated;
        let k_max = spec.draft_len.min(remaining - 1).min(ctx - seq.ids.len());
        // State clone only for drafters that read it (self-drafting);
        // the n-gram drafter rounds never pay it.
        let base = if spec.drafter.wants_state() {
            Some(
                seq.dec
                    .snapshot()
                    .ok_or_else(|| anyhow!("speculative decoding needs snapshot support"))?,
            )
        } else {
            None
        };
        spec.draft.clear();
        spec.drafter.propose(
            &DraftCtx {
                ids: &seq.ids,
                state: base.as_ref(),
                eot: cfg.stop_at_eot.then_some(tok.eot),
            },
            k_max,
            &mut spec.draft,
        )?;
        spec.draft.truncate(k_max);
        let k = spec.draft.len();

        // Scoring pass: feed `last, d_1..d_k`.
        let rows = k + 1;
        let fused = spec.fused;
        if fused {
            // One fused multi-row pass for the whole block; rewind
            // replaces the per-position snapshots.
            let vocab = seq.dec.manifest().vocab;
            spec.block.clear();
            spec.block.push(seq.last);
            spec.block.extend_from_slice(&spec.draft);
            let logits = seq.dec.step_batch(&spec.block)?;
            for i in 0..rows {
                let row = &logits[i * vocab..(i + 1) * vocab];
                if spec.logits.len() <= i {
                    spec.logits.push(row.to_vec());
                } else {
                    spec.logits[i].clear();
                    spec.logits[i].extend_from_slice(row);
                }
            }
            spec.stats.fused_passes += 1;
            spec.stats.fused_rows += rows as u64;
        } else {
            // Sequential fallback: record the logit row and a state
            // snapshot at every position (the restore targets).
            spec.snaps.clear();
            for i in 0..=k {
                let t = if i == 0 { seq.last } else { spec.draft[i - 1] };
                let logits = seq.dec.step(t)?;
                if spec.logits.len() <= i {
                    spec.logits.push(logits.to_vec());
                } else {
                    spec.logits[i].clear();
                    spec.logits[i].extend_from_slice(logits);
                }
                let snap = seq
                    .dec
                    .snapshot()
                    .ok_or_else(|| anyhow!("speculative decoding needs snapshot support"))?;
                spec.snaps.push(snap);
            }
        }

        // Accept pass: emit full-model samples until one disagrees with
        // the draft (or a stop condition fires at its plain-decode
        // boundary).
        let mut finish: Option<FinishReason> = None;
        let mut emitted = 0usize;
        let mut matched = 0u64;
        for i in 0..=k {
            if i > 0 && (seq.ids.len() >= ctx || seq.ids.len() - seq.prompt_len >= seq.budget) {
                // Plain decoding would stop here without sampling; the
                // outer loop re-fires the reason on its next entry.
                break;
            }
            let next = sample_logits(&spec.logits[i], cfg, &mut seq.rng);
            if cfg.stop_at_eot && next == tok.eot {
                finish = Some(FinishReason::Eot);
                break;
            }
            seq.ids.push(next);
            seq.last = next;
            emitted += 1;
            sliced += 1;
            if let Some(o) = obs {
                note_token(seq.id, seq.submitted, &mut seq.last_token_at, o);
            }
            if let Some(out) = seq.stream.as_mut() {
                let text_delta = out.sd.push(tok, next);
                out.emit(TokenEvent::Token { request_id: seq.id, token: next, text_delta });
                if out.dead {
                    finish = Some(FinishReason::Cancelled);
                    break;
                }
            }
            if i < k && next == spec.draft[i] {
                matched += 1;
            } else {
                break;
            }
        }
        spec.stats.rounds += 1;
        spec.stats.drafted += k as u64;
        spec.stats.accepted += matched;
        spec.stats.emitted += emitted as u64;
        if let (Some(o), Some(t0)) = (obs, round_t0) {
            o.registry.record_verify_round(t0.elapsed());
        }
        if let Some(f) = finish {
            // Terminal: the decoder's state is past the emitted history,
            // but a finished sequence's state is never read again (the
            // session is reset at its next admission).
            return Ok(Some(f));
        }
        // Rewind so the consumed tokens are exactly the emitted history
        // (`last, x_0..x_{m-2}`); x_{m-1} stays pending.  Fused: keep
        // the emitted prefix of the batch (a full-acceptance round
        // needs no rewind at all).  Sequential: restore the matching
        // snapshot.
        if fused {
            if emitted < rows {
                seq.dec.rewind_batch(emitted)?;
            }
        } else {
            seq.dec.restore(&spec.snaps[emitted - 1])?;
        }
        if quantum > 0 && sliced >= quantum {
            return Ok(None);
        }
    }
}

/// Tear a finished sequence down into its completion, recovering the
/// decoder for the free pool.  A streaming sequence emits its terminal
/// [`TokenEvent::Done`] here (with the detokenizer's final flush), so
/// consumers always see the completion on the stream itself.
fn complete<D: Decoder>(
    seq: Active<D>,
    tok: &Tokenizer,
    finish: FinishReason,
    obs: Option<&ObsRuntime>,
) -> (D, usize, Completion) {
    let Active {
        dec, ix, id, prompt, ids, prompt_len, cached_prefix_len, spec, stream, submitted, ..
    } = seq;
    if let Some(o) = obs {
        if o.counters {
            o.registry.inc_finished(finish.label());
            if let Some(s) = spec.as_ref() {
                o.registry.spec.add(&s.stats);
            }
        }
        if let Some(now) = o.now() {
            let e2e = now.duration_since(submitted);
            o.registry.record_e2e(e2e);
            let st = spec.as_ref().map(|s| &s.stats);
            o.emit(RequestEvent::Finished {
                request_id: id,
                finish: finish.label().into(),
                tokens_generated: (ids.len() - prompt_len) as u64,
                e2e_ms: e2e.as_secs_f64() * 1e3,
                mixer: dec.manifest().variant.clone(),
                precision: dec.precision().label().into(),
                drafter: spec.as_ref().map(|s| s.drafter_label.clone()),
                spec_rounds: st.map_or(0, |s| s.rounds),
                spec_drafted: st.map_or(0, |s| s.drafted),
                spec_accepted: st.map_or(0, |s| s.accepted),
                cached_prefix_len: cached_prefix_len as u64,
            });
        }
    }
    let completion = Completion {
        request_id: id,
        prompt,
        completion: tok.decode(&ids[prompt_len..]),
        tokens_generated: ids.len() - prompt_len,
        cached_prefix_len,
        spec: spec.map(|s| s.stats),
        finish,
    };
    if let Some(mut out) = stream {
        let text_delta = out.sd.finish();
        out.emit(TokenEvent::Done { text_delta, completion: completion.clone() });
    }
    (dec, ix, completion)
}

// ---------------------------------------------------------------------------
// Single-threaded driver (also the generate / generate_batch wrapper core)
// ---------------------------------------------------------------------------

/// Continuous batching on the current thread: breadth-first over the
/// active set in `quantum`-token slices; a finishing sequence's decoder
/// immediately admits the next pending job.  `decoders.len()` is the
/// effective `max_active`.
pub(crate) fn run_local<D: Decoder>(
    decoders: &mut [D],
    tok: &Tokenizer,
    jobs: Vec<Job>,
    cfg: &SampleCfg,
    quantum: usize,
    cache: Option<&PrefixCache>,
    spec: Option<&SpecCfg>,
    obs: Option<&ObsRuntime>,
    out: &mut [Option<Completion>],
) -> Result<()> {
    if decoders.is_empty() && !jobs.is_empty() {
        bail!("serve: {} requests but no decode sessions", jobs.len());
    }
    let mut free: VecDeque<&mut D> = decoders.iter_mut().collect();
    let mut pending: VecDeque<Job> = jobs.into();
    let mut ready: VecDeque<Active<&mut D>> = VecDeque::new();
    loop {
        // Admission: fill every free session before stepping (job order
        // meets decoder order, so fixed-membership callers get the same
        // decoder↔prompt pairing the old round-robin loop had).  Jobs
        // past their queue-wait deadline finish as TimedOut right here —
        // anywhere in the queue, not just the front — consuming no
        // session.
        reap_expired_queue(&mut pending, obs, |ix, completion| out[ix] = Some(completion));
        while !pending.is_empty() {
            let Some(dec) = free.pop_front() else { break };
            let job = pending.pop_front().unwrap();
            ready.push_back(admit(dec, job, cfg, cache, spec, obs)?);
        }
        let Some(mut seq) = ready.pop_front() else { break };
        match advance(&mut seq, tok, cfg, quantum, obs)? {
            Some(finish) => {
                let (dec, ix, completion) = complete(seq, tok, finish, obs);
                out[ix] = Some(completion);
                free.push_back(dec);
            }
            None => ready.push_back(seq),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Threaded driver: worker pool over disjoint sessions
// ---------------------------------------------------------------------------

/// State behind the scheduler mutex.  Workers hold the lock only to move
/// sequences between queues — prefill and decode run outside it.
struct Shared {
    pending: VecDeque<Job>,
    free: Vec<NativeDecoder>,
    ready: VecDeque<Active<NativeDecoder>>,
    /// Batch completions by output slot.  Streaming sequences deliver
    /// through their sinks instead, so a resident scheduler never
    /// accumulates here.
    done: Vec<(usize, Completion)>,
    /// Admitted but unfinished sequences (in `ready` or claimed by a
    /// worker).  `inflight == 0 && pending.is_empty()` is the drain
    /// condition.
    inflight: usize,
    /// When set, workers exit once drained.  Batch runs start with it
    /// set (drain-and-return); a resident [`StreamScheduler`] sets it on
    /// shutdown.
    shutdown: bool,
    failed: Option<anyhow::Error>,
}

impl Shared {
    /// Mark the scheduler failed and abandon every queued/readied
    /// sequence.  Dropping the jobs drops their event `Sender`s, so
    /// every waiting [`TokenStream`] sees disconnect (recv → `None`)
    /// instead of blocking forever — without this, a resident
    /// scheduler's consumers (and a front-end joining their connection
    /// threads) would hang on requests no worker will ever run.
    fn fail(&mut self, e: anyhow::Error) {
        if self.failed.is_none() {
            self.failed = Some(e);
        }
        self.pending.clear();
        self.ready.clear();
    }
}

fn run_parallel(
    model: &Arc<Model>,
    tok: &Tokenizer,
    jobs: Vec<Job>,
    cfg: &ServeCfg,
    n_sessions: usize,
    cache: Option<&PrefixCache>,
    obs: Option<&ObsRuntime>,
    out: &mut [Option<Completion>],
) -> Result<()> {
    let workers = cfg.threads.min(jobs.len()).max(1);
    let shared = Mutex::new(Shared {
        pending: jobs.into(),
        free: (0..n_sessions).map(|_| model.session()).collect(),
        ready: VecDeque::new(),
        done: Vec::new(),
        inflight: 0,
        shutdown: true, // batch mode: drain and return
        failed: None,
    });
    let wake = Condvar::new();

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| worker(&shared, &wake, tok, cfg, cache, obs));
        }
    });

    // A worker panic would have re-raised when the scope closed above,
    // so the lock cannot be poisoned here.
    let shared = shared.into_inner().expect("workers joined without panicking");
    if let Some(e) = shared.failed {
        return Err(e);
    }
    for (ix, completion) in shared.done {
        out[ix] = Some(completion);
    }
    Ok(())
}

/// What a worker claimed under the lock.
enum Work {
    Admit(Job, NativeDecoder),
    Step(Active<NativeDecoder>),
}

/// Unwind guard: a worker that panics **outside** the lock (decoder or
/// tensor code) would otherwise strand its claimed sequence's `inflight`
/// count and leave the siblings waiting forever.  On a panicking unwind
/// this flags `failed` and wakes everyone, so the siblings exit, the
/// scope joins, and `std::thread::scope` re-raises the panic instead of
/// hanging.  (A panic taken *while holding* the lock poisons it, which
/// already crashes the siblings on their `expect` — also not a hang.)
struct PanicGuard<'a> {
    shared: &'a Mutex<Shared>,
    wake: &'a Condvar,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            if let Ok(mut g) = self.shared.lock() {
                g.fail(anyhow!("serve: a worker thread panicked"));
            }
            self.wake.notify_all();
        }
    }
}

fn worker(
    shared: &Mutex<Shared>,
    wake: &Condvar,
    tok: &Tokenizer,
    cfg: &ServeCfg,
    cache: Option<&PrefixCache>,
    obs: Option<&ObsRuntime>,
) {
    let _guard = PanicGuard { shared, wake };
    loop {
        let work = {
            let mut g = shared.lock().expect("scheduler lock poisoned");
            loop {
                if g.failed.is_some() {
                    return;
                }
                // Queue-wait fairness: jobs past their admission deadline
                // finish as TimedOut inline, consuming no session.  This
                // runs before the ready-pop so a saturated scheduler
                // (ready never empty) still honors the budget instead of
                // delivering the timeout only when a session frees — and
                // it sweeps the whole queue, so (with EDF or mixed
                // deadlines) an expired job cannot hide behind a live
                // one: notification latency is one scheduling pass.
                {
                    let s = &mut *g;
                    let (pending, done) = (&mut s.pending, &mut s.done);
                    reap_expired_queue(pending, obs, |ix, c| done.push((ix, c)));
                }
                if let Some(o) = obs {
                    if o.counters {
                        o.registry.set_queue_depth(g.pending.len() as u64);
                    }
                }
                if let Some(seq) = g.ready.pop_front() {
                    break Work::Step(seq);
                }
                // Continuous admission: any free session + pending job
                // pairs up immediately — no end-of-batch barrier.
                if !g.pending.is_empty() && !g.free.is_empty() {
                    let job = g.pending.pop_front().unwrap();
                    let dec = g.free.pop().unwrap();
                    g.inflight += 1;
                    break Work::Admit(job, dec);
                }
                if g.shutdown && g.inflight == 0 && g.pending.is_empty() {
                    // Drained: expired-job pops above may have emptied the
                    // queue, so wake any sibling parked on the condvar to
                    // observe the drain too.
                    wake.notify_all();
                    return;
                }
                g = wake.wait(g).expect("scheduler lock poisoned");
            }
        };

        // Heavy work (prefill / quantum of decode steps) off the lock.
        let stepped = match work {
            Work::Admit(job, dec) => {
                admit(dec, job, &cfg.sample, cache, cfg.speculation.as_ref(), obs).and_then(
                    |mut seq| {
                        advance(&mut seq, tok, &cfg.sample, cfg.quantum, obs).map(|f| (seq, f))
                    },
                )
            }
            Work::Step(mut seq) => {
                advance(&mut seq, tok, &cfg.sample, cfg.quantum, obs).map(|f| (seq, f))
            }
        };

        match stepped {
            Ok((seq, None)) => {
                let mut g = shared.lock().expect("scheduler lock poisoned");
                if g.failed.is_none() {
                    g.ready.push_back(seq);
                } // else: a sibling failed while we were decoding — drop
                  // the sequence (and its sink) rather than strand it.
                drop(g);
                wake.notify_one();
            }
            Ok((seq, Some(finish))) => {
                // Streaming sequences already delivered their completion
                // through the sink inside `complete`; only batch slots
                // collect into `done`.
                let streamed = seq.stream.is_some();
                let (dec, ix, completion) = complete(seq, tok, finish, obs);
                let mut g = shared.lock().expect("scheduler lock poisoned");
                if !streamed {
                    g.done.push((ix, completion));
                }
                g.free.push(dec);
                g.inflight -= 1;
                drop(g);
                // A session freed AND possibly the last sequence: wake
                // everyone so admitters and the drain check both run.
                wake.notify_all();
            }
            Err(e) => {
                let mut g = shared.lock().expect("scheduler lock poisoned");
                g.inflight -= 1;
                g.fail(e);
                drop(g);
                wake.notify_all();
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Resident scheduler: streaming submissions against always-on workers
// ---------------------------------------------------------------------------

/// Everything the resident workers share, behind one `Arc`.
struct ResidentInner {
    shared: Mutex<Shared>,
    wake: Condvar,
    tok: Tokenizer,
    cfg: ServeCfg,
    model: Arc<Model>,
    /// Shared prompt-head snapshot cache (None when disabled); lives as
    /// long as the scheduler, so every submission can hit heads earlier
    /// submissions paid for.
    cache: Option<Arc<PrefixCache>>,
    /// Telemetry runtime (None with [`ObsCfg::off`]): the metrics
    /// registry behind `GET /healthz` and `GET /metrics`, plus the
    /// optional request log.
    obs: Option<Arc<ObsRuntime>>,
    /// Per-user fixed-window admission quotas (None when
    /// [`ServeCfg::quota`] is unset): charged in [`StreamScheduler::try_submit`]
    /// before a job is queued.
    quota: Option<QuotaState>,
}

/// A resident continuous-batching scheduler: the worker pool stays up
/// between requests, so callers (in-process, or a cross-process
/// front-end like [`crate::server::HttpServer`]) can
/// [`submit`](Self::submit) at any time and stream tokens back as they
/// decode.
///
/// All [`ServeCfg::max_active`] sessions are created up front and
/// recycled across requests; admission, time slicing and determinism are
/// exactly the batch [`Scheduler`]'s (same worker loop), so streamed
/// text is byte-identical to batch and to sequential decoding.
///
/// Shutdown is graceful: [`shutdown`](Self::shutdown) (also run on drop)
/// stops accepting, drains every queued and in-flight request, and joins
/// the workers.
pub struct StreamScheduler {
    inner: Arc<ResidentInner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl StreamScheduler {
    /// Validate the config ([`ServeCfg::validate_resident`]), build the
    /// session pool, and spawn the worker threads.
    pub fn start(model: Arc<Model>, tok: Tokenizer, cfg: ServeCfg) -> Result<Self> {
        cfg.validate_resident()?;
        cfg.validate_model(&model)?;
        let free = (0..cfg.max_active).map(|_| model.session()).collect();
        let obs = ObsRuntime::from_cfg(&cfg.obs);
        if let Some(o) = &obs {
            o.registry
                .set_model_resident(model.precision().label(), model.resident_weight_bytes() as u64);
        }
        let cache = (cfg.prefix_cache_size > 0).then(|| {
            Arc::new(match &obs {
                Some(o) => PrefixCache::with_counters(
                    model.fingerprint(),
                    cfg.prefix_cache_size,
                    o.registry.cache_counters(),
                ),
                None => PrefixCache::new(model.fingerprint(), cfg.prefix_cache_size),
            })
        });
        let quota = cfg.quota.clone().map(QuotaState::new);
        let inner = Arc::new(ResidentInner {
            shared: Mutex::new(Shared {
                pending: VecDeque::new(),
                free,
                ready: VecDeque::new(),
                done: Vec::new(),
                inflight: 0,
                shutdown: false,
                failed: None,
            }),
            wake: Condvar::new(),
            tok,
            cfg,
            model,
            cache,
            obs,
            quota,
        });
        let workers = (0..inner.cfg.threads)
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || {
                    worker(
                        &inner.shared,
                        &inner.wake,
                        &inner.tok,
                        &inner.cfg,
                        inner.cache.as_deref(),
                        inner.obs.as_deref(),
                    )
                })
            })
            .collect();
        Ok(StreamScheduler { inner, workers: Mutex::new(workers) })
    }

    pub fn model(&self) -> &Arc<Model> {
        &self.inner.model
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.inner.tok
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.inner.cfg
    }

    /// The shared prefix cache (None when disabled); its
    /// [`stats`](PrefixCache::stats) feed `GET /healthz`.
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.inner.cache.as_ref()
    }

    /// Aggregate speculative-decoding acceptance counters across every
    /// request this scheduler has finished (all zeros while
    /// [`ServeCfg::speculation`] is off, or with telemetry disabled) —
    /// `GET /healthz`.  A view over the metrics registry.
    pub fn spec_stats(&self) -> SpecStats {
        self.inner.obs.as_ref().map(|o| o.registry.spec.snapshot()).unwrap_or_default()
    }

    /// The metrics registry this scheduler records into (None with
    /// [`ObsCfg::off`]) — `GET /metrics` renders it via
    /// [`MetricsRegistry::render_prometheus`].
    pub fn metrics(&self) -> Option<&Arc<MetricsRegistry>> {
        self.inner.obs.as_ref().map(|o| &o.registry)
    }

    /// Submit one request; its events stream back on the returned
    /// [`TokenStream`].  An invalid prompt yields an immediate
    /// [`TokenEvent::Done`] with [`FinishReason::Rejected`] (mirroring
    /// batch semantics — one user's bad prompt is data, not an error);
    /// `Err` means the scheduler itself is not accepting (shut down, or
    /// a worker failed).  Admission-control refusals (queue depth,
    /// quota — see [`try_submit`](Self::try_submit)) surface here as a
    /// plain error; front-ends that need the `Retry-After` hint call
    /// `try_submit` directly.
    pub fn submit(&self, req: Request) -> Result<TokenStream> {
        self.try_submit(req).map_err(|e| match e {
            SubmitError::Unavailable(err) => err,
            SubmitError::Throttled(adm) => anyhow!("serve: throttled: {adm}"),
        })
    }

    /// [`submit`](Self::submit) with a structured error: a refusal by
    /// admission control — pending queue at [`ServeCfg::max_queue_depth`],
    /// or the request's `user` over its [`QuotaCfg`] window — comes back
    /// as [`SubmitError::Throttled`] carrying a [`Retry-After`
    /// hint](AdmissionError::retry_after), so an HTTP front-end can
    /// answer 429 instead of a generic 503.  Nothing is queued or
    /// charged on a throttled submit.  With `max_queue_depth == 0` and
    /// no quota configured (the defaults), behavior is byte-identical
    /// to the pre-backpressure path.
    pub fn try_submit(&self, req: Request) -> std::result::Result<TokenStream, SubmitError> {
        let Request { id, prompt, max_new_tokens, user, deadline_ms } = req;
        let (tx, rx) = channel();
        let stream = TokenStream { request_id: id, rx };
        let submitted = Instant::now();
        let obs = self.inner.obs.as_deref();
        let ids = match encode_prompt(&self.inner.model.manifest, &self.inner.tok, &prompt) {
            Ok(ids) => ids,
            Err(e) => {
                note_rejected(obs, id, submitted);
                let completion = Completion {
                    request_id: id,
                    prompt,
                    completion: String::new(),
                    tokens_generated: 0,
                    cached_prefix_len: 0,
                    spec: None,
                    finish: FinishReason::Rejected(format!("{e:#}")),
                };
                let _ = tx.send(TokenEvent::Done { text_delta: String::new(), completion });
                return Ok(stream);
            }
        };
        let budget = max_new_tokens.unwrap_or(self.inner.cfg.sample.max_new_tokens);
        let deadline = deadline_ms
            .map(|ms| submitted + Duration::from_millis(ms))
            .or_else(|| self.inner.cfg.max_queue_wait.map(|d| submitted + d));
        let job = Job {
            ix: 0, // unused: streaming completions travel by sink
            id,
            budget,
            prompt,
            ids,
            deadline,
            submitted,
            sink: Some(tx),
        };
        {
            let mut g = self.inner.shared.lock().expect("scheduler lock poisoned");
            if g.shutdown {
                return Err(SubmitError::Unavailable(anyhow!("serve: scheduler is shut down")));
            }
            if let Some(e) = &g.failed {
                return Err(SubmitError::Unavailable(anyhow!("serve: scheduler failed: {e:#}")));
            }
            // Reap before measuring depth: expired jobs should never
            // count against a live submitter's admission budget.
            {
                let s = &mut *g;
                let (pending, done) = (&mut s.pending, &mut s.done);
                reap_expired_queue(pending, obs, |ix, c| done.push((ix, c)));
            }
            let limit = self.inner.cfg.max_queue_depth;
            if limit > 0 && g.pending.len() >= limit {
                let depth = g.pending.len();
                let adm = AdmissionError::QueueFull {
                    depth,
                    limit,
                    retry_after: queue_retry_after(depth, self.inner.cfg.max_active),
                };
                drop(g);
                note_throttled(obs, id, submitted, &adm);
                return Err(SubmitError::Throttled(adm));
            }
            if let (Some(q), Some(user)) = (&self.inner.quota, user.as_deref()) {
                let tokens = (job.ids.len() + budget) as u64;
                if let Err(adm) = q.try_charge(user, tokens) {
                    drop(g);
                    note_throttled(obs, id, submitted, &adm);
                    return Err(SubmitError::Throttled(adm));
                }
                if let Some(o) = obs {
                    if o.counters {
                        o.registry.add_quota_tokens(tokens);
                    }
                }
            }
            enqueue(&mut g.pending, job, self.inner.cfg.edf);
            if let Some(o) = obs {
                if o.counters {
                    o.registry.set_queue_depth(g.pending.len() as u64);
                }
            }
        }
        self.inner.wake.notify_one();
        Ok(stream)
    }

    /// Graceful shutdown: stop accepting, drain every queued and active
    /// request (their streams still complete), join the workers.
    /// Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        if let Ok(mut g) = self.inner.shared.lock() {
            g.shutdown = true;
        }
        self.inner.wake.notify_all();
        let workers = std::mem::take(&mut *self.workers.lock().expect("worker list lock"));
        for h in workers {
            let _ = h.join();
        }
    }
}

impl Drop for StreamScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LayerInfo;
    use crate::config::Manifest;
    use crate::infer::{weights, ModelWeights};
    use crate::tokenizer::trainer as tok_trainer;

    fn model(vocab: usize, ctx: usize) -> Arc<Model> {
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
        ];
        let m = Manifest::synthetic("hsm_ab", layers, 8, ctx, vocab, 1);
        let flat = weights::seeded_flat(&m, 21);
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
    }

    fn tok() -> Tokenizer {
        let text = crate::corpus::generate(11, 60);
        tok_trainer::train(&text, 280).unwrap()
    }

    #[test]
    fn scheduler_and_convenience_fn_agree() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = ServeCfg {
            max_active: 2,
            threads: 1,
            quantum: 3,
            sample: SampleCfg { max_new_tokens: 6, seed: 4, ..Default::default() },
            ..Default::default()
        };
        let reqs = |s: u64| {
            vec![Request::new(s, "Once upon a time"), Request::new(s + 1, "Lily likes cats")]
        };
        let a = serve(&model, &tok, reqs(0), &cfg).unwrap();
        let b = Scheduler::new(Arc::clone(&model), cfg).unwrap().serve(&tok, reqs(0)).unwrap();
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.completion, y.completion);
            assert_eq!(x.request_id, y.request_id);
        }
    }

    #[test]
    fn rejected_request_does_not_fail_the_batch() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = ServeCfg {
            threads: 1,
            sample: SampleCfg { max_new_tokens: 4, ..Default::default() },
            ..Default::default()
        };
        let reqs = vec![Request::new(0, "Once upon a time"), Request::new(1, "")];
        let comps = serve(&model, &tok, reqs, &cfg).unwrap();
        assert_eq!(comps.len(), 2);
        assert!(comps[0].tokens_generated > 0 || comps[0].finish == FinishReason::Eot);
        assert!(matches!(comps[1].finish, FinishReason::Rejected(_)));
        assert_eq!(comps[1].tokens_generated, 0);
    }

    #[test]
    fn zero_capacity_or_threads_is_an_error() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let bad = |max_active, threads| ServeCfg {
            max_active,
            threads,
            ..Default::default()
        };
        let req = vec![Request::new(0, "hi there")];
        assert!(serve(&model, &tok, req.clone(), &bad(0, 1)).is_err());
        assert!(serve(&model, &tok, req, &bad(1, 0)).is_err());
    }

    /// Degenerate configs fail at construction with a clear message, not
    /// at serve time (and never as a hang).
    #[test]
    fn resident_schedulers_validate_cfg_at_construction() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        for (max_active, threads, quantum) in [(0, 1, 1), (1, 0, 1), (1, 1, 0)] {
            let cfg = ServeCfg { max_active, threads, quantum, ..Default::default() };
            assert!(cfg.validate_resident().is_err());
            assert!(Scheduler::new(Arc::clone(&model), cfg.clone()).is_err());
            assert!(StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).is_err());
        }
        // quantum 0 stays valid for the one-shot batch call.
        let cfg = ServeCfg { quantum: 0, threads: 1, ..Default::default() };
        assert!(cfg.validate().is_ok());
        assert!(serve(&model, &tok, vec![Request::new(0, "hi there")], &cfg).is_ok());
    }

    /// [`ServeCfg::precision`] must name what the model was actually
    /// loaded as: mismatches fail at construction in every scheduler
    /// shape, and a matching int8 cfg serves deterministically.
    #[test]
    fn cfg_precision_must_match_the_loaded_model() {
        let tok = tok();
        let f32_model = model(tok.vocab_size(), 48);
        let q_model = {
            let layers = vec![
                LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
                LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
            ];
            let m = Manifest::synthetic("hsm_ab", layers, 8, 48, tok.vocab_size(), 1);
            let flat = weights::seeded_flat(&m, 21);
            let w = ModelWeights::from_flat(&m, &flat).unwrap();
            Model::shared_with_precision(m, w, Precision::Int8).unwrap()
        };
        let f32_cfg = ServeCfg { threads: 1, ..Default::default() };
        let int8_cfg = ServeCfg { threads: 1, precision: Precision::Int8, ..Default::default() };
        let req = vec![Request::new(0, "Once upon a time")];
        assert!(serve(&f32_model, &tok, req.clone(), &int8_cfg).is_err());
        assert!(serve(&q_model, &tok, req.clone(), &f32_cfg).is_err());
        assert!(Scheduler::new(Arc::clone(&f32_model), int8_cfg.clone()).is_err());
        assert!(StreamScheduler::start(Arc::clone(&q_model), tok.clone(), f32_cfg).is_err());
        let a = serve(&q_model, &tok, req.clone(), &int8_cfg).unwrap();
        let b = serve(&q_model, &tok, req, &int8_cfg).unwrap();
        assert_eq!(a[0].completion, b[0].completion, "int8 serving must be deterministic");
        assert!(a[0].tokens_generated > 0 || a[0].finish == FinishReason::Eot);
    }

    #[test]
    fn empty_request_batch_is_empty() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let comps = serve(&model, &tok, Vec::new(), &ServeCfg::default()).unwrap();
        assert!(comps.is_empty());
    }

    /// Saturated max_active=1 scheduler, deterministic deadlines: the
    /// request holding the session completes; the one queued past its
    /// budget finishes TimedOut without decoding a single token.
    #[test]
    fn queued_past_deadline_times_out_without_decoding() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let sample = SampleCfg { max_new_tokens: 5, seed: 2, ..Default::default() };
        let long_ago = Instant::now()
            .checked_sub(Duration::from_secs(60))
            .unwrap_or_else(Instant::now);
        let job = |ix: usize, deadline: Option<Instant>| Job {
            ix,
            id: ix as u64,
            budget: sample.max_new_tokens,
            prompt: "Once upon a time".to_string(),
            ids: tok.encode("Once upon a time"),
            deadline,
            submitted: Instant::now(),
            sink: None,
        };
        let jobs = vec![
            job(0, Some(Instant::now() + Duration::from_secs(3600))),
            job(1, Some(long_ago)),
            job(2, None),
        ];
        let mut out = vec![None, None, None];
        let mut sessions = vec![model.session()]; // max_active = 1: saturated
        run_local(&mut sessions, &tok, jobs, &sample, 2, None, None, None, &mut out).unwrap();
        let out: Vec<Completion> = out.into_iter().map(Option::unwrap).collect();
        assert_ne!(out[0].finish, FinishReason::TimedOut);
        assert!(out[0].tokens_generated > 0);
        assert_eq!(out[1].finish, FinishReason::TimedOut);
        assert_eq!(out[1].tokens_generated, 0);
        assert_eq!(out[1].completion, "");
        assert_ne!(out[2].finish, FinishReason::TimedOut);
    }

    /// End-to-end budget semantics on both drivers: a zero budget expires
    /// every request (admission always happens strictly after intake); a
    /// generous budget changes nothing.
    #[test]
    fn zero_queue_wait_times_out_every_request() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let reqs = || vec![Request::new(0, "Once upon a time"), Request::new(1, "Lily likes cats")];
        let base = ServeCfg {
            max_active: 2,
            quantum: 2,
            sample: SampleCfg { max_new_tokens: 4, seed: 6, ..Default::default() },
            ..Default::default()
        };
        for threads in [1, 2] {
            let zero = ServeCfg {
                threads,
                max_queue_wait: Some(Duration::ZERO),
                ..base.clone()
            };
            for c in serve(&model, &tok, reqs(), &zero).unwrap() {
                assert_eq!(c.finish, FinishReason::TimedOut, "threads={threads}");
                assert_eq!(c.tokens_generated, 0);
            }
            let lax = ServeCfg {
                threads,
                max_queue_wait: Some(Duration::from_secs(3600)),
                ..base.clone()
            };
            let unlimited = ServeCfg { threads, ..base.clone() };
            let a = serve(&model, &tok, reqs(), &lax).unwrap();
            let b = serve(&model, &tok, reqs(), &unlimited).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.completion, y.completion);
                assert_ne!(x.finish, FinishReason::TimedOut);
            }
        }
    }

    /// Streaming taps are pure observers: deltas concatenate to the
    /// batch/sequential completion text, token events count the sampled
    /// tokens, and the stream ends with exactly one Done.
    #[test]
    fn stream_scheduler_matches_batch_serve() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = ServeCfg {
            max_active: 2,
            threads: 2,
            quantum: 2,
            sample: SampleCfg { max_new_tokens: 6, seed: 4, ..Default::default() },
            ..Default::default()
        };
        let prompts = ["Once upon a time", "Lily likes cats", "Jack went to"];
        let reqs: Vec<Request> =
            prompts.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
        let batch = serve(&model, &tok, reqs.clone(), &cfg).unwrap();

        let sched =
            StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap();
        let streams: Vec<TokenStream> =
            reqs.into_iter().map(|r| sched.submit(r).unwrap()).collect();
        for (stream, want) in streams.into_iter().zip(&batch) {
            let mut events = 0usize;
            let mut streamed = String::new();
            let mut done = None;
            for ev in stream {
                match ev {
                    TokenEvent::Token { text_delta, .. } => {
                        events += 1;
                        streamed.push_str(&text_delta);
                    }
                    TokenEvent::Done { text_delta, completion } => {
                        streamed.push_str(&text_delta);
                        done = Some(completion);
                    }
                }
            }
            let done = done.expect("stream ended without Done");
            assert_eq!(done.request_id, want.request_id);
            assert_eq!(streamed, want.completion, "request {}", want.request_id);
            assert_eq!(done.completion, want.completion);
            assert_eq!(events, want.tokens_generated);
            assert_eq!(done.finish, want.finish);
        }
        sched.shutdown();
        assert!(sched.submit(Request::new(9, "hi")).is_err(), "post-shutdown submit must fail");
    }

    /// Dropping a TokenStream mid-decode (client gone) must not perturb
    /// any other request's text.
    #[test]
    fn dropped_stream_consumer_does_not_change_siblings() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = ServeCfg {
            max_active: 2,
            threads: 2,
            quantum: 1,
            sample: SampleCfg { max_new_tokens: 8, seed: 12, ..Default::default() },
            ..Default::default()
        };
        let reference = serve(
            &model,
            &tok,
            vec![Request::new(0, "Once upon a time"), Request::new(1, "Lily likes cats")],
            &cfg,
        )
        .unwrap();

        let sched = StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap();
        let dropped = sched.submit(Request::new(0, "Once upon a time")).unwrap();
        let kept = sched.submit(Request::new(1, "Lily likes cats")).unwrap();
        drop(dropped);
        let completion = kept.wait(|_| {}).expect("surviving stream finishes");
        assert_eq!(completion.completion, reference[1].completion);
        sched.shutdown();
    }

    /// A dropped consumer cancels decoding at the next sampled token:
    /// the sequence finishes as Cancelled with its session freed, never
    /// burning the rest of its budget on an unobservable stream.
    #[test]
    fn dropped_sink_cancels_decoding_early() {
        let tok = tok();
        let model = model(tok.vocab_size(), 200);
        let sample = SampleCfg {
            max_new_tokens: 150,
            seed: 4,
            stop_at_eot: false,
            ..Default::default()
        };
        let (tx, rx) = channel();
        drop(rx); // consumer vanished before the first token
        let job = Job {
            ix: 0,
            id: 0,
            budget: sample.max_new_tokens,
            prompt: "Once upon a time".to_string(),
            ids: tok.encode("Once upon a time"),
            deadline: None,
            submitted: Instant::now(),
            sink: Some(tx),
        };
        let mut out = vec![None];
        let mut sessions = vec![model.session()];
        run_local(&mut sessions, &tok, vec![job], &sample, 4, None, None, None, &mut out).unwrap();
        let c = out.pop().unwrap().unwrap();
        assert_eq!(c.finish, FinishReason::Cancelled);
        assert_eq!(c.tokens_generated, 1, "dead sink is noticed after one token");

        // A batch job (no sink) with the same budget runs to its cap —
        // cancellation is strictly a streaming-consumer concern.
        let job = Job {
            ix: 0,
            id: 0,
            budget: sample.max_new_tokens,
            prompt: "Once upon a time".to_string(),
            ids: tok.encode("Once upon a time"),
            deadline: None,
            submitted: Instant::now(),
            sink: None,
        };
        let mut out = vec![None];
        let mut sessions = vec![model.session()];
        run_local(&mut sessions, &tok, vec![job], &sample, 4, None, None, None, &mut out).unwrap();
        let c = out.pop().unwrap().unwrap();
        assert_ne!(c.finish, FinishReason::Cancelled);
        assert!(c.tokens_generated > 1);
    }

    /// The scheduler's prefix cache persists across serve calls: the
    /// second batch hits the heads the first batch paid for, and the
    /// text stays byte-identical to an uncached scheduler.
    #[test]
    fn prefix_cache_hits_across_batches_without_changing_text() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = |prefix_cache_size| ServeCfg {
            max_active: 2,
            threads: 1,
            quantum: 3,
            prefix_cache_size,
            sample: SampleCfg { max_new_tokens: 6, seed: 4, ..Default::default() },
            ..Default::default()
        };
        let reqs = || {
            vec![Request::new(0, "Once upon a time"), Request::new(1, "Once upon a time")]
        };
        let cold = Scheduler::new(Arc::clone(&model), cfg(0)).unwrap();
        let warm = Scheduler::new(Arc::clone(&model), cfg(8)).unwrap();
        assert!(cold.prefix_cache().is_none());
        let reference = cold.serve(&tok, reqs()).unwrap();
        for pass in 0..2 {
            let got = warm.serve(&tok, reqs()).unwrap();
            for (c, r) in got.iter().zip(&reference) {
                assert_eq!(c.completion, r.completion, "pass {pass}: cache changed text");
                assert_eq!(c.finish, r.finish);
                assert_eq!(r.cached_prefix_len, 0, "caching disabled ⇒ always cold");
            }
            // Request 0 seeds the cache on the first pass; its duplicate
            // (and every later pass) restores the whole head.
            let head_len = tok.encode("Once upon a time").len() - 1;
            if pass == 0 {
                assert_eq!(got[0].cached_prefix_len, 0);
            } else {
                assert_eq!(got[0].cached_prefix_len, head_len);
            }
            assert_eq!(got[1].cached_prefix_len, head_len);
        }
        let stats = warm.prefix_cache().unwrap().stats();
        assert!(stats.hits >= 3, "expected ≥3 hits, got {}", stats.hits);
        // Identical heads share entries: one per stride boundary at most.
        assert!(
            stats.entries >= 1 && stats.entries <= 2,
            "identical heads must share entries, got {}",
            stats.entries
        );
    }

    /// Speculative decoding is a pure accelerator: byte-identical
    /// completions with it on or off, for both drafters and both
    /// driver shapes, with acceptance accounting on the completion.
    #[test]
    fn speculative_serving_matches_plain_serving() {
        use crate::infer::speculate::DrafterKind;
        let tok = tok();
        let model = model(tok.vocab_size(), 64);
        let reqs = || {
            vec![
                Request::new(0, "Once upon a time"),
                Request::new(1, "Lily likes cats and dogs"),
                Request::new(2, "Once upon a time"),
            ]
        };
        let base = ServeCfg {
            max_active: 2,
            quantum: 2,
            prefix_cache_size: 0,
            sample: SampleCfg { max_new_tokens: 10, seed: 7, ..Default::default() },
            ..Default::default()
        };
        for threads in [1usize, 2] {
            let plain = serve(
                &model,
                &tok,
                reqs(),
                &ServeCfg { threads, ..base.clone() },
            )
            .unwrap();
            assert!(plain.iter().all(|c| c.spec.is_none()), "speculation off ⇒ no stats");
            for drafter in [
                DrafterKind::NGram { max_ngram: 3 },
                DrafterKind::Shallow { layers: 0 },
            ] {
                let cfg = ServeCfg {
                    threads,
                    speculation: Some(SpecCfg { drafter, draft_len: 3, ..Default::default() }),
                    ..base.clone()
                };
                let spec = serve(&model, &tok, reqs(), &cfg).unwrap();
                for (p, s) in plain.iter().zip(&spec) {
                    assert_eq!(
                        p.completion, s.completion,
                        "{drafter:?} threads={threads}: speculation changed text"
                    );
                    assert_eq!(p.finish, s.finish);
                    assert_eq!(p.tokens_generated, s.tokens_generated);
                    let st = s.spec.expect("speculation on ⇒ stats present");
                    assert!(st.rounds >= 1);
                    assert_eq!(
                        st.emitted as usize, s.tokens_generated,
                        "every emitted token is accounted to a round"
                    );
                    assert!(st.accepted <= st.drafted);
                }
            }
        }
    }

    /// Invalid prompts reject through the stream itself (uniform with
    /// batch semantics).
    #[test]
    fn stream_submit_rejects_bad_prompt_via_done_event() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let sched = StreamScheduler::start(
            Arc::clone(&model),
            tok.clone(),
            ServeCfg { threads: 1, ..Default::default() },
        )
        .unwrap();
        let stream = sched.submit(Request::new(7, "")).unwrap();
        let completion = stream.wait(|_| {}).expect("rejection still delivers Done");
        assert!(matches!(completion.finish, FinishReason::Rejected(_)));
        assert_eq!(completion.tokens_generated, 0);
    }

    /// Every [`FinishReason`] variant has exactly one entry in
    /// [`crate::obs::FINISH_LABELS`] — so `inc_finished` can never see a
    /// label it doesn't know.  The no-wildcard match makes adding a
    /// variant without updating this list a compile error.
    #[test]
    fn every_finish_reason_has_a_metrics_label() {
        let all = [
            FinishReason::Eot,
            FinishReason::MaxTokens,
            FinishReason::CtxFull,
            FinishReason::TimedOut,
            FinishReason::Cancelled,
            FinishReason::Rejected(String::new()),
            FinishReason::Throttled(String::new()),
        ];
        for f in &all {
            match f {
                FinishReason::Eot
                | FinishReason::MaxTokens
                | FinishReason::CtxFull
                | FinishReason::TimedOut
                | FinishReason::Cancelled
                | FinishReason::Rejected(_)
                | FinishReason::Throttled(_) => {}
            }
            assert!(
                crate::obs::FINISH_LABELS.contains(&f.label()),
                "label {:?} missing from obs::FINISH_LABELS",
                f.label()
            );
        }
        assert_eq!(
            crate::obs::FINISH_LABELS.len(),
            all.len(),
            "FINISH_LABELS and FinishReason must stay 1:1"
        );
    }

    /// The reap sweeps the *whole* queue: an expired job behind a live
    /// one is collected, order among survivors is preserved, and
    /// nothing is decoded for the expired slot.
    #[test]
    fn reap_collects_expired_jobs_anywhere_in_the_queue() {
        let tok = tok();
        let long_ago =
            Instant::now().checked_sub(Duration::from_secs(60)).unwrap_or_else(Instant::now);
        let job = |ix: usize, deadline: Option<Instant>| Job {
            ix,
            id: ix as u64,
            budget: 4,
            prompt: "Once upon a time".to_string(),
            ids: tok.encode("Once upon a time"),
            deadline,
            submitted: Instant::now(),
            sink: None,
        };
        let far = Some(Instant::now() + Duration::from_secs(3600));
        let mut pending: VecDeque<Job> =
            vec![job(0, far), job(1, Some(long_ago)), job(2, None), job(3, Some(long_ago))]
                .into();
        let mut reaped = Vec::new();
        reap_expired_queue(&mut pending, None, |ix, c| reaped.push((ix, c)));
        assert_eq!(reaped.iter().map(|(ix, _)| *ix).collect::<Vec<_>>(), vec![1, 3]);
        for (_, c) in &reaped {
            assert_eq!(c.finish, FinishReason::TimedOut);
            assert_eq!(c.tokens_generated, 0);
        }
        assert_eq!(pending.iter().map(|j| j.ix).collect::<Vec<_>>(), vec![0, 2]);
    }

    /// EDF insertion: earliest deadline first, deadline-free jobs last,
    /// FIFO among equals; off = plain FIFO.
    #[test]
    fn edf_enqueue_orders_by_deadline() {
        let tok = tok();
        let base = Instant::now() + Duration::from_secs(100);
        let job = |ix: usize, deadline: Option<Instant>| Job {
            ix,
            id: ix as u64,
            budget: 4,
            prompt: "hi there".to_string(),
            ids: tok.encode("hi there"),
            deadline,
            submitted: Instant::now(),
            sink: None,
        };
        let mut q: VecDeque<Job> = VecDeque::new();
        enqueue(&mut q, job(0, None), true);
        enqueue(&mut q, job(1, Some(base + Duration::from_secs(30))), true);
        enqueue(&mut q, job(2, Some(base)), true);
        enqueue(&mut q, job(3, Some(base + Duration::from_secs(30))), true);
        enqueue(&mut q, job(4, None), true);
        assert_eq!(q.iter().map(|j| j.ix).collect::<Vec<_>>(), vec![2, 1, 3, 0, 4]);
        let mut fifo: VecDeque<Job> = VecDeque::new();
        enqueue(&mut fifo, job(0, None), false);
        enqueue(&mut fifo, job(1, Some(base)), false);
        assert_eq!(fifo.iter().map(|j| j.ix).collect::<Vec<_>>(), vec![0, 1]);
    }

    /// EDF is pure scheduling: with generous deadlines, completions are
    /// byte-identical to FIFO (per-request RNG streams make admission
    /// order irrelevant to sampled text).
    #[test]
    fn edf_never_changes_sampled_text() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let reqs = || {
            let mut a = Request::new(0, "Once upon a time");
            a.deadline_ms = Some(3_600_000);
            let mut b = Request::new(1, "Lily likes cats");
            b.deadline_ms = Some(1_800_000);
            vec![a, b, Request::new(2, "Jack went to")]
        };
        let base = ServeCfg {
            max_active: 2,
            quantum: 2,
            sample: SampleCfg { max_new_tokens: 6, seed: 4, ..Default::default() },
            ..Default::default()
        };
        for threads in [1usize, 2] {
            let fifo = serve(&model, &tok, reqs(), &ServeCfg { threads, ..base.clone() }).unwrap();
            let edf =
                serve(&model, &tok, reqs(), &ServeCfg { threads, edf: true, ..base.clone() })
                    .unwrap();
            for (x, y) in fifo.iter().zip(&edf) {
                assert_eq!(x.request_id, y.request_id, "results stay in request order");
                assert_eq!(x.completion, y.completion, "threads={threads}: EDF changed text");
                assert_eq!(x.finish, y.finish);
            }
        }
    }

    /// Per-user quotas on the batch path: the first request charges the
    /// window, the same user's second request is Throttled, another
    /// user and an anonymous request pass.
    #[test]
    fn batch_quota_throttles_per_user() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = ServeCfg {
            threads: 1,
            quota: Some(QuotaCfg { max_requests: 1, ..Default::default() }),
            sample: SampleCfg { max_new_tokens: 4, seed: 3, ..Default::default() },
            ..Default::default()
        };
        let reqs = vec![
            Request::new(0, "Once upon a time").with_user("alice"),
            Request::new(1, "Lily likes cats").with_user("alice"),
            Request::new(2, "Jack went to").with_user("bob"),
            Request::new(3, "hi there"),
        ];
        let comps = serve(&model, &tok, reqs, &cfg).unwrap();
        assert_ne!(comps[0].finish.label(), "throttled");
        assert!(matches!(comps[1].finish, FinishReason::Throttled(_)), "{:?}", comps[1].finish);
        assert_eq!(comps[1].tokens_generated, 0);
        assert_ne!(comps[2].finish.label(), "throttled", "other users have their own window");
        assert_ne!(comps[3].finish.label(), "throttled", "anonymous requests bypass quotas");
    }

    /// Token quotas charge prompt + budget pessimistically at admission
    /// and refuse without charging: a refused request does not consume
    /// window budget a later, smaller one could use.
    #[test]
    fn quota_state_charges_tokens_pessimistically() {
        let q = QuotaState::new(QuotaCfg { max_tokens: 10, ..Default::default() });
        assert!(q.try_charge("u", 6).is_ok());
        let err = q.try_charge("u", 6).unwrap_err();
        assert!(matches!(err, AdmissionError::QuotaExceeded { what: "token", .. }));
        assert!(err.retry_after() >= Duration::from_secs(1));
        // The refusal charged nothing: 4 more tokens still fit.
        assert!(q.try_charge("u", 4).is_ok());
        assert!(q.try_charge("v", 10).is_ok(), "windows are per-user");
    }

    /// Resident backpressure: with max_queue_depth=1 on a saturated
    /// max_active=1 scheduler, the queue accepts one waiter and
    /// throttles the next with a Retry-After hint — never an unbounded
    /// queue.  Plain submit() surfaces the same refusal as an error.
    #[test]
    fn stream_scheduler_throttles_at_queue_depth() {
        let tok = tok();
        // A large context + no-EOT sampling keeps request 0 decoding for
        // thousands of steps, so it reliably holds the single session
        // while we probe admission.
        let model = model(tok.vocab_size(), 4096);
        let cfg = ServeCfg {
            max_active: 1,
            threads: 1,
            quantum: 1,
            max_queue_depth: 1,
            prefix_cache_size: 0,
            sample: SampleCfg {
                max_new_tokens: 4000,
                seed: 5,
                stop_at_eot: false,
                ..Default::default()
            },
            ..Default::default()
        };
        let sched = StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap();
        let first = sched.try_submit(Request::new(0, "Once upon a time")).unwrap();
        // Wait until request 0 holds the session (first token arrives),
        // so the next submissions are guaranteed to queue.
        let mut first_it = first.into_iter();
        let _ = first_it.next().expect("request 0 produces at least one event");
        let _queued = sched.try_submit(Request::new(1, "Lily likes cats")).unwrap();
        match sched.try_submit(Request::new(2, "Jack went to")) {
            Err(SubmitError::Throttled(adm)) => {
                assert!(matches!(adm, AdmissionError::QueueFull { depth: 1, limit: 1, .. }));
                assert!(adm.retry_after() >= Duration::from_secs(1));
            }
            other => panic!("expected Throttled, got {:?}", other.map(|s| s.request_id)),
        }
        let err = sched.submit(Request::new(3, "hi there")).unwrap_err();
        assert!(format!("{err:#}").contains("throttled"), "{err:#}");
        drop(first_it);
        drop(_queued);
        sched.shutdown();
    }
