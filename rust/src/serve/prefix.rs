//! Shared prefix cache: decode-session snapshots at prompt-head
//! boundaries, keyed by (model fingerprint, token prefix), with
//! longest-prefix-match lookup.
//!
//! The serving-side payoff of HSM's O(1)-state decoding: after consuming
//! a token prefix, an HSM layer's entire state is a ring of `max_shift`
//! activation rows — a small, **fixed-size** [`SessionState`] that can
//! be snapshotted and forked, unlike a full KV cache whose size grows
//! with the prefix.  When hundreds of requests share a system-prompt
//! head, the first request pays the prefill once and every later request
//! restores the snapshot and prefills only its uncached tail, which is
//! the dominant cost for short completions.
//!
//! Correctness rests on two properties:
//!
//! * **Bit-exact restore** — decoding from a restored snapshot is
//!   byte-identical to cold-prefilling the same tokens
//!   (`rust/tests/fork_parity.rs` pins this for every mixer kind), so a
//!   cache hit can never change sampled text.
//! * **Fingerprint keying** — every lookup and insert carries the
//!   requesting model's fingerprint (manifest shape + weight bits);
//!   a mismatch is a miss, so state never crosses model boundaries.
//!
//! The cache is a size-bounded LRU over whole entries, shared by all
//! scheduler workers behind one `Mutex` (lookups clone the snapshot out,
//! so the lock is never held across a prefill).  Hit/miss/insertion/
//! eviction counters feed `GET /healthz` and the serve benches.
//!
//! **Quantization-aware storage:** snapshots taken under a quantized
//! serving precision carry an int8 image per ring row (the f32 row is
//! *defined as* its dequantization), so [`PrefixCache::insert`]
//! [`SessionState::compact`]s every snapshot before storing — dropping
//! the f32 ring rows and roughly quartering the entry's ring bytes —
//! and [`PrefixCache::lookup`] [`SessionState::hydrate`]s the clone it
//! hands out, byte-exactly.  F32 snapshots have no images, compact is a
//! no-op, and nothing changes.  The serving precision is part of the
//! model fingerprint, so the precision is folded into the cache key by
//! construction: an int8 server's snapshots can never hit an f32 (or
//! int4) server's cache.  Per-entry byte and precision accounting feeds
//! the `hsm_prefix_cache_resident_bytes` /
//! `hsm_prefix_cache_quantized_entries` gauges.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::infer::SessionState;
use crate::obs::CacheCounters;

/// Snapshot stride during prefill: admission publishes a snapshot every
/// this many tokens of the prompt head (at absolute positions — every
/// request sharing a head agrees on the boundaries) plus one at the full
/// head.  Requests that share a long head but differ in their tails hit
/// the last common boundary and prefill only from there; exact duplicate
/// prompts hit the full head.  Smaller stride = finer sharing but more
/// cache entries per distinct head.
pub const SNAPSHOT_STRIDE: usize = 16;

/// Counter snapshot (from [`PrefixCache::stats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixCacheStats {
    /// Entries currently cached.
    pub entries: usize,
    /// Entry cap the cache was built with.
    pub capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Approximate heap bytes of all resident snapshots (compacted
    /// quantized entries count their at-rest size).
    pub resident_bytes: u64,
    /// Resident entries stored compacted at a quantized precision.
    pub quantized_entries: u64,
}

impl PrefixCacheStats {
    /// Hits over lookups (0.0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    /// Stored at rest: compacted when the snapshot carries a complete
    /// quantized ring image (see [`SessionState::compact`]).
    state: SessionState,
    /// Recency stamp (global tick at last touch) — the LRU ordering.
    stamp: u64,
    /// At-rest heap bytes (for the resident-bytes gauge; recorded at
    /// insert so the evict-side decrement always balances).
    bytes: u64,
    /// Whether the entry is stored compacted (quantized image only).
    quantized: bool,
}

struct Inner {
    entries: HashMap<Vec<u32>, Entry>,
    /// Distinct prefix lengths present → entry count at that length, so
    /// a longest-prefix lookup probes only lengths that actually exist
    /// (one hash per candidate length, longest first).
    lens: BTreeMap<usize, usize>,
    tick: u64,
}

/// Size-bounded LRU of [`SessionState`] snapshots keyed by
/// (model fingerprint, token prefix).  Shared (behind `Arc`) by every
/// worker of a [`crate::serve::Scheduler`] / [`crate::serve::StreamScheduler`].
pub struct PrefixCache {
    fingerprint: u64,
    capacity: usize,
    inner: Mutex<Inner>,
    /// Event counters — private by default, the metrics registry's
    /// cells when a scheduler wires the cache into its telemetry
    /// ([`PrefixCache::with_counters`]), so `GET /healthz` and
    /// `GET /metrics` read the very same atomics.
    counters: Arc<CacheCounters>,
}

impl PrefixCache {
    /// A cache for one model (`fingerprint` from
    /// [`crate::infer::Model::fingerprint`]), holding at most `capacity`
    /// snapshots (clamped to ≥ 1), counting into a private
    /// [`CacheCounters`].
    pub fn new(fingerprint: u64, capacity: usize) -> Self {
        Self::with_counters(fingerprint, capacity, Arc::new(CacheCounters::default()))
    }

    /// [`PrefixCache::new`] recording into shared counter cells —
    /// typically [`crate::obs::MetricsRegistry::cache_counters`].
    pub fn with_counters(fingerprint: u64, capacity: usize, counters: Arc<CacheCounters>) -> Self {
        PrefixCache {
            fingerprint,
            capacity: capacity.max(1),
            inner: Mutex::new(Inner { entries: HashMap::new(), lens: BTreeMap::new(), tick: 0 }),
            counters,
        }
    }

    /// The model fingerprint this cache serves.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("prefix cache lock").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Longest-prefix-match lookup: the cached snapshot for the longest
    /// stored prefix of `tokens`, cloned out together with its length.
    /// A hit refreshes the entry's recency.  A fingerprint mismatch (or
    /// empty `tokens`) is a plain miss — never an error — so callers
    /// fall back to a cold prefill.
    ///
    /// Entries stored compacted (quantized precision) are
    /// [`SessionState::hydrate`]d on the clone, outside the lock — the
    /// caller always receives a ready-to-restore state, byte-identical
    /// to the one inserted.
    pub fn lookup(&self, fingerprint: u64, tokens: &[u32]) -> Option<(usize, SessionState)> {
        if fingerprint != self.fingerprint || tokens.is_empty() {
            self.counters.miss();
            return None;
        }
        let mut g = self.inner.lock().expect("prefix cache lock");
        g.tick += 1;
        let tick = g.tick;
        // Candidate lengths that exist in the cache, longest first.
        let lens: Vec<usize> = g.lens.range(..=tokens.len()).map(|(&l, _)| l).collect();
        for &len in lens.iter().rev() {
            if let Some(e) = g.entries.get_mut(&tokens[..len]) {
                e.stamp = tick;
                let mut state = e.state.clone();
                drop(g);
                state.hydrate();
                self.counters.hit();
                return Some((len, state));
            }
        }
        drop(g);
        self.counters.miss();
        None
    }

    /// Insert (or refresh) the snapshot for a full token prefix.
    /// `state.position()` must equal `tokens.len()` — the snapshot must
    /// be taken exactly at the prefix boundary.  At capacity, the
    /// least-recently-used entry is evicted.  Fingerprint mismatches and
    /// empty prefixes are ignored.
    pub fn insert(&self, fingerprint: u64, tokens: &[u32], mut state: SessionState) {
        if fingerprint != self.fingerprint || tokens.is_empty() {
            return;
        }
        debug_assert_eq!(
            state.position(),
            tokens.len(),
            "snapshot position must sit at the prefix boundary"
        );
        // Store at the serving precision: a quantized-precision snapshot
        // drops its f32 ring rows here (no-op for f32 snapshots), and
        // lookup() rehydrates byte-exactly.  Done outside the lock.
        state.compact();
        let quantized = state.is_compacted();
        let bytes = state.resident_bytes() as u64;
        let mut g = self.inner.lock().expect("prefix cache lock");
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g.entries.get_mut(tokens) {
            // Racing inserts of the same prefix (two identical prompts
            // admitted concurrently): keep one, refresh recency.
            e.stamp = tick;
            return;
        }
        if g.entries.len() >= self.capacity {
            // O(entries) LRU scan; the cap is small by construction.
            if let Some(victim) =
                g.entries.iter().min_by_key(|(_, e)| e.stamp).map(|(k, _)| k.clone())
            {
                if let Some(evicted) = g.entries.remove(&victim) {
                    if let Some(n) = g.lens.get_mut(&victim.len()) {
                        *n -= 1;
                        if *n == 0 {
                            g.lens.remove(&victim.len());
                        }
                    }
                    self.counters.evicted(evicted.bytes, evicted.quantized);
                }
            }
        }
        *g.lens.entry(tokens.len()).or_insert(0) += 1;
        g.entries.insert(tokens.to_vec(), Entry { state, stamp: tick, bytes, quantized });
        drop(g);
        self.counters.inserted(bytes, quantized);
    }

    /// The shared counter cells this cache records into.
    pub fn counters(&self) -> &Arc<CacheCounters> {
        &self.counters
    }

    /// Point-in-time counter snapshot — a view over the same cells
    /// `GET /metrics` renders.
    pub fn stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            entries: self.len(),
            capacity: self.capacity,
            hits: self.counters.hits.load(Ordering::Relaxed),
            misses: self.counters.misses.load(Ordering::Relaxed),
            insertions: self.counters.insertions.load(Ordering::Relaxed),
            evictions: self.counters.evictions.load(Ordering::Relaxed),
            resident_bytes: self.counters.resident_bytes.load(Ordering::Relaxed),
            quantized_entries: self.counters.quantized_entries.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LayerInfo, Manifest};
    use crate::infer::{weights, Decoder, Model, ModelWeights};
    use std::sync::Arc;

    fn model(seed: u64) -> Arc<Model> {
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
        ];
        let m = Manifest::synthetic("hsm_ab", layers, 8, 64, 300, 1);
        let flat = weights::seeded_flat(&m, seed);
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
    }

    /// Snapshot of `model` after prefilling `tokens`.
    fn snap(model: &Arc<Model>, tokens: &[u32]) -> SessionState {
        let mut s = model.session();
        s.prefill(tokens).unwrap();
        s.snapshot().unwrap()
    }

    #[test]
    fn longest_prefix_match_wins() {
        let md = model(1);
        let fp = md.fingerprint();
        let cache = PrefixCache::new(fp, 8);
        cache.insert(fp, &[1, 2], snap(&md, &[1, 2]));
        cache.insert(fp, &[1, 2, 3, 4], snap(&md, &[1, 2, 3, 4]));

        let (len, st) = cache.lookup(fp, &[1, 2, 3, 4, 5]).expect("hit");
        assert_eq!(len, 4);
        assert_eq!(st.position(), 4);
        let (len, _) = cache.lookup(fp, &[1, 2, 9]).expect("hit on shorter prefix");
        assert_eq!(len, 2);
        assert!(cache.lookup(fp, &[9, 9]).is_none());
        assert!(cache.lookup(fp, &[]).is_none(), "empty prefix is a miss");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (2, 2));
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let md = model(1);
        let fp = md.fingerprint();
        let cache = PrefixCache::new(fp, 2);
        cache.insert(fp, &[1], snap(&md, &[1]));
        cache.insert(fp, &[2], snap(&md, &[2]));
        // Touch [1] so [2] becomes the LRU victim.
        assert!(cache.lookup(fp, &[1]).is_some());
        cache.insert(fp, &[3], snap(&md, &[3]));

        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.lookup(fp, &[1]).is_some(), "recently used entry survives");
        assert!(cache.lookup(fp, &[2]).is_none(), "LRU entry evicted");
        assert!(cache.lookup(fp, &[3]).is_some());
    }

    #[test]
    fn fingerprint_mismatch_is_a_miss_and_insert_noop() {
        let md = model(1);
        let other = model(2);
        assert_ne!(md.fingerprint(), other.fingerprint());
        let cache = PrefixCache::new(md.fingerprint(), 4);
        cache.insert(md.fingerprint(), &[1, 2], snap(&md, &[1, 2]));

        assert!(cache.lookup(other.fingerprint(), &[1, 2]).is_none());
        cache.insert(other.fingerprint(), &[7, 8], snap(&other, &[7, 8]));
        assert_eq!(cache.len(), 1, "foreign-model insert must be ignored");
        assert!(cache.lookup(md.fingerprint(), &[1, 2]).is_some());
    }

    /// Quantized-precision snapshots are stored compacted (at-rest
    /// bytes well below the hydrated size), hits hand back a hydrated,
    /// restore-ready state whose continued decode is byte-identical,
    /// and the resident-bytes/quantized-entries gauges balance across
    /// insert and evict.
    #[test]
    fn quantized_snapshots_are_stored_compacted_and_restore_byte_exact() {
        use crate::infer::Precision;
        let f32_md = model(1);
        let flat = weights::seeded_flat(&f32_md.manifest, 1);
        let md = Model::shared_with_precision(
            f32_md.manifest.clone(),
            ModelWeights::from_flat(&f32_md.manifest, &flat).unwrap(),
            Precision::Int4,
        )
        .unwrap();
        let fp = md.fingerprint();
        assert_ne!(fp, f32_md.fingerprint(), "precision must be folded into the cache key");
        let cache = PrefixCache::new(fp, 2);

        let prefix = [5u32, 9, 3, 7];
        let full = snap(&md, &prefix);
        let hydrated_bytes = full.resident_bytes() as u64;
        cache.insert(fp, &prefix, full);
        let s = cache.stats();
        assert_eq!(s.quantized_entries, 1, "int4 snapshot must be stored compacted");
        assert!(
            s.resident_bytes < hydrated_bytes,
            "at-rest bytes {} must undercut hydrated {}",
            s.resident_bytes,
            hydrated_bytes
        );

        // The hit is hydrated and decodes byte-identically to a cold
        // session that stepped the same prefix.
        let (len, state) = cache.lookup(fp, &[5, 9, 3, 7, 2]).expect("hit");
        assert_eq!(len, 4);
        assert!(!state.is_compacted(), "lookup must hand out hydrated state");
        let mut warm = md.session_from(state).unwrap();
        let mut cold = md.session();
        cold.prefill(&prefix).unwrap();
        let a: Vec<u32> = warm.step(2).unwrap().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = cold.step(2).unwrap().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "decode from a quantized cache hit diverged");

        // Evicting returns the gauges to a consistent state.
        cache.insert(fp, &[1], snap(&md, &[1]));
        cache.insert(fp, &[2], snap(&md, &[2])); // evicts the LRU entry
        let s = cache.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.quantized_entries, 2);

        // F32 snapshots are stored as-is: no quantized entries.
        let fcache = PrefixCache::new(f32_md.fingerprint(), 2);
        fcache.insert(f32_md.fingerprint(), &[1, 2], snap(&f32_md, &[1, 2]));
        let s = fcache.stats();
        assert_eq!(s.quantized_entries, 0);
        assert!(s.resident_bytes > 0);
    }

    #[test]
    fn duplicate_insert_refreshes_without_growing() {
        let md = model(1);
        let fp = md.fingerprint();
        let cache = PrefixCache::new(fp, 2);
        cache.insert(fp, &[1, 2], snap(&md, &[1, 2]));
        cache.insert(fp, &[1, 2], snap(&md, &[1, 2]));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().insertions, 1);
    }
}
