//! Report drivers: regenerate every table and figure of the paper.
//!
//! | driver     | paper artifact                                        |
//! |------------|-------------------------------------------------------|
//! | `table1`   | Table 1 — val loss + time/epoch for the 11 configs    |
//! | `table2`   | Table 2 — learned (a, b) per layer of HSM (a,b)       |
//! | `table3`   | Table 3 — completions of the 11 qualitative prompts   |
//! | `fig7`     | Figure 7 — val-loss-vs-epoch curves                   |
//! | `fig8`     | Figure 8 — val-accuracy-vs-loss point cloud           |
//!
//! Every driver is generic over an [`EngineFactory`] so the full pipeline
//! is unit-tested with `MockEngine`; production uses [`PjrtFactory`].
//! Reports land in `reports/<preset>/` as markdown + CSV, and every run
//! appends to EXPERIMENTS.md manually (see Makefile targets).

use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

#[cfg(feature = "pjrt")]
use crate::config::artifacts_root;
use crate::config::Manifest;
use crate::coordinator::{Trainer, TrainerOptions, TrainOutcome};
use crate::corpus;
use crate::data::Dataset;
use crate::generation::{self, SampleCfg, TABLE3_PROMPTS};
use crate::infer::{Model, ModelWeights};
use crate::report_sinks;
use crate::serve;
#[cfg(feature = "pjrt")]
use crate::runtime::PjrtEngine;
use crate::runtime::StepEngine;
use crate::tokenizer::{trainer as tok_trainer, Tokenizer};

/// Creates engines per variant — PJRT in production, mock in tests.
pub trait EngineFactory {
    fn create(&self, variant: &str) -> Result<Box<dyn StepEngine>>;
}

/// Production factory: loads `artifacts/<preset>/<variant>/`.
#[cfg(feature = "pjrt")]
pub struct PjrtFactory {
    pub root: PathBuf,
    pub preset: String,
}

#[cfg(feature = "pjrt")]
impl PjrtFactory {
    pub fn new(preset: &str) -> Self {
        PjrtFactory { root: artifacts_root(), preset: preset.to_string() }
    }
}

#[cfg(feature = "pjrt")]
impl EngineFactory for PjrtFactory {
    fn create(&self, variant: &str) -> Result<Box<dyn StepEngine>> {
        let manifest = Manifest::load_variant(&self.root, &self.preset, variant)?;
        Ok(Box::new(PjrtEngine::new(manifest)?))
    }
}

/// Everything a report run needs.
pub struct ExperimentCtx {
    pub preset: String,
    pub reports_dir: PathBuf,
    /// Corpus size to synthesise (bytes) when no real dump is given.
    pub corpus_bytes: usize,
    pub corpus_path: Option<PathBuf>,
    pub corpus_seed: u64,
    pub data_seed: u64,
    pub train_seed: u64,
    pub epochs: usize,
    pub max_steps: Option<usize>,
    pub eval_batches: Option<usize>,
    pub log_every: usize,
}

impl ExperimentCtx {
    pub fn new(preset: &str) -> Self {
        ExperimentCtx {
            preset: preset.to_string(),
            reports_dir: PathBuf::from("reports").join(preset),
            corpus_bytes: 1 << 20,
            corpus_path: None,
            corpus_seed: 1234,
            data_seed: 42,
            train_seed: 42,
            epochs: 2,
            max_steps: None,
            eval_batches: Some(8),
            log_every: 0,
        }
    }

    fn options(&self) -> TrainerOptions {
        TrainerOptions {
            epochs: self.epochs,
            max_steps: self.max_steps,
            seed: self.train_seed,
            eval_batches: self.eval_batches,
            log_every: self.log_every,
            record_steps: false,
        }
    }
}

/// Corpus → tokenizer → datasets, matched to one manifest's (ctx, vocab).
///
/// The tokenizer is cached per (vocab, corpus seed/bytes) under the
/// reports dir: BPE training is the most expensive CPU substrate step and
/// all variants of a preset share vocab.
pub fn build_data(ctx: &ExperimentCtx, m: &Manifest) -> Result<(Tokenizer, Dataset, Dataset)> {
    let text = corpus::load_or_generate(
        ctx.corpus_path.as_deref(),
        ctx.corpus_seed,
        ctx.corpus_bytes,
    )?;
    std::fs::create_dir_all(&ctx.reports_dir).ok();
    let tok_path = ctx.reports_dir.join(format!(
        "tokenizer_v{}_s{}_b{}.json",
        m.vocab, ctx.corpus_seed, ctx.corpus_bytes
    ));
    let tok = if tok_path.exists() {
        Tokenizer::load(&tok_path)?
    } else {
        let t = tok_trainer::train(&text, m.vocab)
            .with_context(|| format!("training BPE tokenizer (vocab {})", m.vocab))?;
        t.save(&tok_path)?;
        t
    };
    if tok.vocab_size() > m.vocab {
        return Err(anyhow!(
            "tokenizer produced {} tokens > model vocab {}",
            tok.vocab_size(),
            m.vocab
        ));
    }
    let (train, val, stats) = Dataset::build(&text, &tok, m.ctx, 0.9, ctx.data_seed)?;
    println!(
        "data[{}]: {} stories ({} filtered), {} windows → {} train / {} val",
        ctx.preset, stats.stories_total, stats.stories_filtered, stats.windows,
        train.len(), val.len()
    );
    Ok((tok, train, val))
}

/// Train one variant end-to-end and return its outcome.
pub fn train_variant(
    factory: &dyn EngineFactory,
    ctx: &ExperimentCtx,
    variant: &str,
) -> Result<(Box<dyn StepEngine>, TrainOutcome)> {
    let mut engine = factory.create(variant)?;
    let (_tok, train, val) = build_data(ctx, engine.manifest())?;
    let outcome = Trainer::new(engine.as_mut(), ctx.options()).run(&train, &val)?;
    Ok((engine, outcome))
}

/// Run the sweep over `variants`, returning all outcomes.
pub fn sweep(
    factory: &dyn EngineFactory,
    ctx: &ExperimentCtx,
    variants: &[&str],
) -> Result<Vec<TrainOutcome>> {
    let mut outcomes = Vec::new();
    for v in variants {
        println!("=== training {v} ({}) ===", ctx.preset);
        let (_, outcome) = train_variant(factory, ctx, v)?;
        println!(
            "    {v}: val loss {:.4}, {:.1}s/epoch",
            outcome.final_val_loss(),
            outcome.secs_per_epoch()
        );
        outcomes.push(outcome);
    }
    Ok(outcomes)
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------

/// Table 1: per-variant validation loss and seconds/epoch (absolute and
/// relative to GPT, which carries the paper's timing claims).
pub fn table1_markdown(outcomes: &[TrainOutcome], manifests: &[Manifest]) -> String {
    let gpt_secs = outcomes
        .iter()
        .find(|o| o.variant == "gpt")
        .map(|o| o.secs_per_epoch())
        .unwrap_or(f64::NAN);
    let best = outcomes
        .iter()
        .map(|o| o.final_val_loss())
        .fold(f32::INFINITY, f32::min);
    let rows: Vec<Vec<String>> = outcomes
        .iter()
        .map(|o| {
            let m = manifests.iter().find(|m| m.variant == o.variant);
            let display = m.map(|m| m.display_name.clone()).unwrap_or_else(|| o.variant.clone());
            let ffn = m
                .map(|m| {
                    let mut ffns: Vec<usize> = m.layers.iter().map(|l| l.ffn).collect();
                    ffns.dedup();
                    ffns.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("/")
                })
                .unwrap_or_default();
            let heads = m
                .map(|m| {
                    let mut hs: Vec<usize> = m.layers.iter().map(|l| l.heads).collect();
                    hs.dedup();
                    hs.iter().map(|h| h.to_string()).collect::<Vec<_>>().join("/")
                })
                .unwrap_or_default();
            let loss = o.final_val_loss();
            let loss_s = if (loss - best).abs() < 1e-6 {
                format!("**{loss:.4}**")
            } else {
                format!("{loss:.4}")
            };
            vec![
                display,
                ffn,
                heads,
                loss_s,
                format!("{:.1}", o.secs_per_epoch()),
                format!("{:.2}×", o.secs_per_epoch() / gpt_secs),
            ]
        })
        .collect();
    report_sinks::markdown_table(
        &["Version", "FFN size", "# Heads", "Loss", "sec/epoch", "time vs GPT"],
        &rows,
    )
}

pub fn run_table1(
    factory: &dyn EngineFactory,
    ctx: &ExperimentCtx,
    variants: &[&str],
) -> Result<String> {
    let outcomes = sweep(factory, ctx, variants)?;
    let manifests: Vec<Manifest> = variants
        .iter()
        .filter_map(|v| factory.create(v).ok().map(|e| e.manifest().clone()))
        .collect();
    let md = table1_markdown(&outcomes, &manifests);
    std::fs::create_dir_all(&ctx.reports_dir).ok();
    std::fs::write(ctx.reports_dir.join("table1.md"), &md)?;
    // Also drop the raw per-epoch series for fig7/fig8 reuse.
    write_outcomes_csv(ctx, &outcomes)?;
    Ok(md)
}

fn write_outcomes_csv(ctx: &ExperimentCtx, outcomes: &[TrainOutcome]) -> Result<()> {
    let rows = report_sinks::fig8_rows(outcomes);
    report_sinks::write_csv(
        &ctx.reports_dir.join("epochs.csv"),
        &["variant", "epoch", "val_loss", "val_acc"],
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Table 2
// ---------------------------------------------------------------------------

/// Table 2: learned scalar taps a, b per layer of the HSM (a, b) model.
pub fn table2_markdown(engine: &dyn StepEngine) -> Result<String> {
    let m = engine.manifest();
    let params = engine.get_params()?;
    let mut row_a = vec!["a".to_string()];
    let mut row_b = vec!["b".to_string()];
    for (l, _) in m.layers.iter().enumerate() {
        let find = |suffix: &str| -> Option<f32> {
            let name = format!("layer{l}.{suffix}");
            m.params
                .iter()
                .position(|p| p.name == name)
                .and_then(|i| params.get(i))
                .and_then(|v| v.first().copied())
        };
        row_a.push(find("mix_a").map(|x| format!("{x:.4}")).unwrap_or_else(|| "—".into()));
        row_b.push(find("mix_b").map(|x| format!("{x:.4}")).unwrap_or_else(|| "—".into()));
    }
    let mut header = vec!["".to_string()];
    header.extend((0..m.layers.len()).map(|l| format!("Layer {l}")));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    Ok(report_sinks::markdown_table(&header_refs, &[row_a, row_b]))
}

pub fn run_table2(factory: &dyn EngineFactory, ctx: &ExperimentCtx) -> Result<String> {
    let (engine, _) = train_variant(factory, ctx, "hsm_ab")?;
    let md = table2_markdown(engine.as_ref())?;
    std::fs::create_dir_all(&ctx.reports_dir).ok();
    std::fs::write(ctx.reports_dir.join("table2.md"), &md)?;
    Ok(md)
}

// ---------------------------------------------------------------------------
// Table 3
// ---------------------------------------------------------------------------

/// Greedy Table-3 completions for one trained engine.
///
/// Serving-path wiring: pull the weights out of the engine once, build a
/// shared native [`Model`], and decode the whole prompt suite through
/// the continuous-batching [`serve::Scheduler`] — concurrent sessions,
/// O(1) state per token for pure-HSM stacks, and byte-identical output
/// to sequential decoding (greedy sampling + per-request RNG streams).
/// Engines that cannot export flat parameters (or whose manifest the
/// native engine rejects) fall back to windowed decoding through their
/// own `decode`.
///
/// Prompts longer than the context window are truncated from the left
/// (keep the suffix — it determines the continuation).
fn table3_completions(
    engine: &mut dyn StepEngine,
    tok: &Tokenizer,
    max_new_tokens: usize,
) -> Result<Vec<String>> {
    let cfg = SampleCfg { temperature: 0.0, top_k: 0, max_new_tokens, seed: 0, stop_at_eot: true };
    let manifest = engine.manifest().clone();
    let ctx_len = manifest.ctx;
    let native = engine
        .get_params()
        .ok()
        .and_then(|flat| ModelWeights::from_flat(&manifest, &flat).ok())
        .and_then(|w| Model::shared(manifest, w).ok());

    let mut cells = Vec::with_capacity(TABLE3_PROMPTS.len());
    match native {
        Some(model) => {
            let requests: Vec<serve::Request> = TABLE3_PROMPTS
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let fits = tok.encode(p).len() < ctx_len;
                    let prompt =
                        if fits { (*p).to_string() } else { truncate_prompt(p, tok, ctx_len) };
                    serve::Request { id: i as u64, prompt, max_new_tokens: None }
                })
                .collect();
            let scfg = serve::ServeCfg {
                max_active: 4,
                threads: 2,
                quantum: 8,
                sample: cfg,
                ..Default::default()
            };
            for c in serve::serve(&model, tok, requests, &scfg)? {
                if let serve::FinishReason::Rejected(why) = &c.finish {
                    return Err(anyhow!("table3 prompt rejected: {why}"));
                }
                cells.push(c.completion.replace('\n', " "));
            }
        }
        None => {
            let mut dec = generation::WindowDecoder::new(engine, tok.eot);
            for prompt in TABLE3_PROMPTS {
                let g = generation::generate(&mut dec, tok, prompt, &cfg).or_else(|_| {
                    let short = truncate_prompt(prompt, tok, ctx_len);
                    generation::generate(&mut dec, tok, &short, &cfg)
                })?;
                cells.push(g.completion.replace('\n', " "));
            }
        }
    }
    Ok(cells)
}

/// Table 3: greedy completions of the 11 qualitative prompts, one column
/// per variant, plus a mechanical coherence proxy (see DESIGN.md §6 on why
/// the paper's human color-coding is replaced by a heuristic).
pub fn run_table3(
    factory: &dyn EngineFactory,
    ctx: &ExperimentCtx,
    variants: &[&str],
    max_new_tokens: usize,
) -> Result<String> {
    let mut columns: Vec<(String, Vec<String>)> = Vec::new();
    for v in variants {
        let (mut engine, _) = train_variant(factory, ctx, v)?;
        let (tok, _, _) = build_data(ctx, engine.manifest())?;
        let cells = table3_completions(engine.as_mut(), &tok, max_new_tokens)?;
        columns.push((v.to_string(), cells));
    }
    let mut header = vec!["Prompt".to_string()];
    header.extend(columns.iter().map(|(v, _)| v.clone()));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = TABLE3_PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut row = vec![p.chars().take(60).collect::<String>()];
            row.extend(columns.iter().map(|(_, cells)| cells[i].clone()));
            row
        })
        .collect();
    let md = report_sinks::markdown_table(&header_refs, &rows);
    std::fs::create_dir_all(&ctx.reports_dir).ok();
    std::fs::write(ctx.reports_dir.join("table3.md"), &md)?;
    Ok(md)
}

fn truncate_prompt(prompt: &str, tok: &Tokenizer, ctx: usize) -> String {
    let ids = tok.encode(prompt);
    let keep = ctx.saturating_sub(8).min(ids.len());
    tok.decode(&ids[ids.len() - keep..])
}

// ---------------------------------------------------------------------------
// Figures 7 & 8
// ---------------------------------------------------------------------------

/// Figure 7's model set: GPT, HSM (a,b), Hybrid Multihead [0,6] and the
/// "HSM:[0,1,2,4,5,6]" hybrid (paper Fig. 7 caption).
pub const FIG7_VARIANTS: &[&str] = &["gpt", "hsm_ab", "hybrid_mh_06", "hybrid_l3gpt"];

pub fn run_fig7(
    factory: &dyn EngineFactory,
    ctx: &ExperimentCtx,
    variants: &[&str],
) -> Result<PathBuf> {
    let outcomes = sweep(factory, ctx, variants)?;
    let (header, rows) = report_sinks::fig7_rows(&outcomes);
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let path = ctx.reports_dir.join("fig7.csv");
    report_sinks::write_csv(&path, &header_refs, &rows)?;
    Ok(path)
}

pub fn run_fig8(
    factory: &dyn EngineFactory,
    ctx: &ExperimentCtx,
    variants: &[&str],
) -> Result<(PathBuf, f64)> {
    let outcomes = sweep(factory, ctx, variants)?;
    let rows = report_sinks::fig8_rows(&outcomes);
    let path = ctx.reports_dir.join("fig8.csv");
    report_sinks::write_csv(&path, &["variant", "epoch", "val_loss", "val_acc"], &rows)?;
    // The paper's headline observation: strong anti-correlation.
    let losses: Vec<f64> = outcomes
        .iter()
        .flat_map(|o| o.epochs.iter().map(|e| e.val_loss as f64))
        .collect();
    let accs: Vec<f64> = outcomes
        .iter()
        .flat_map(|o| o.epochs.iter().map(|e| e.val_acc as f64))
        .collect();
    let r = report_sinks::pearson(&losses, &accs);
    Ok((path, r))
}

// ---------------------------------------------------------------------------
// Combined run — train each variant ONCE, emit every table and figure
// ---------------------------------------------------------------------------

/// Everything the paper's evaluation section reports, from a single
/// training pass per variant.
///
/// XLA 0.5.1 spends ~40 s compiling each train_step artifact (measured in
/// EXPERIMENTS.md §Perf), so the one-pass structure — rather than
/// retraining per table — is what makes regenerating the full evaluation
/// practical: per variant we pay one compile + one training run, then
/// derive Table 1/3 rows and the Figure 7/8 series from the same outcome.
pub fn run_all(
    factory: &dyn EngineFactory,
    ctx: &ExperimentCtx,
    variants: &[&str],
    table3_tokens: usize,
) -> Result<String> {
    std::fs::create_dir_all(&ctx.reports_dir).ok();
    let mut outcomes: Vec<TrainOutcome> = Vec::new();
    let mut manifests: Vec<Manifest> = Vec::new();
    let mut table3_cols: Vec<(String, Vec<String>)> = Vec::new();
    let mut table2_md = String::new();
    let mut summary = String::new();

    for v in variants {
        println!("=== {v} ({}) ===", ctx.preset);
        let (mut engine, outcome) = train_variant(factory, ctx, v)?;
        manifests.push(engine.manifest().clone());
        println!(
            "    val loss {:.4}, {:.1}s/epoch",
            outcome.final_val_loss(),
            outcome.secs_per_epoch()
        );

        // Table 2 comes from the trained hsm_ab weights.
        if *v == "hsm_ab" {
            table2_md = table2_markdown(engine.as_ref())?;
        }

        // Table 3 column: greedy completions of the 11 prompts, through
        // the native incremental decoder (windowed fallback).
        let (tok, _, _) = build_data(ctx, engine.manifest())?;
        let cells = table3_completions(engine.as_mut(), &tok, table3_tokens)?;
        table3_cols.push((v.to_string(), cells));
        outcomes.push(outcome);
    }

    // Table 1.
    let t1 = table1_markdown(&outcomes, &manifests);
    std::fs::write(ctx.reports_dir.join("table1.md"), &t1)?;
    summary.push_str("## Table 1\n\n");
    summary.push_str(&t1);

    // Table 2.
    if !table2_md.is_empty() {
        std::fs::write(ctx.reports_dir.join("table2.md"), &table2_md)?;
        summary.push_str("\n## Table 2 (learned a, b of HSM (a,b))\n\n");
        summary.push_str(&table2_md);
    }

    // Table 3.
    let mut header = vec!["Prompt".to_string()];
    header.extend(table3_cols.iter().map(|(v, _)| v.clone()));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = TABLE3_PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut row = vec![p.chars().take(60).collect::<String>()];
            row.extend(table3_cols.iter().map(|(_, c)| c[i].clone()));
            row
        })
        .collect();
    let t3 = report_sinks::markdown_table(&header_refs, &rows);
    std::fs::write(ctx.reports_dir.join("table3.md"), &t3)?;
    summary.push_str("\n## Table 3\n\n");
    summary.push_str(&t3);

    // Figures 7 & 8.
    let (h7, r7) = report_sinks::fig7_rows(&outcomes);
    let h7r: Vec<&str> = h7.iter().map(String::as_str).collect();
    report_sinks::write_csv(&ctx.reports_dir.join("fig7.csv"), &h7r, &r7)?;
    let r8 = report_sinks::fig8_rows(&outcomes);
    report_sinks::write_csv(
        &ctx.reports_dir.join("fig8.csv"),
        &["variant", "epoch", "val_loss", "val_acc"],
        &r8,
    )?;
    let losses: Vec<f64> = outcomes
        .iter()
        .flat_map(|o| o.epochs.iter().map(|e| e.val_loss as f64))
        .collect();
    let accs: Vec<f64> = outcomes
        .iter()
        .flat_map(|o| o.epochs.iter().map(|e| e.val_acc as f64))
        .collect();
    let r = report_sinks::pearson(&losses, &accs);
    summary.push_str(&format!(
        "\n## Figures\n\nfig7.csv and fig8.csv written; pearson(val_loss, val_acc) = {r:.4}\n"
    ));
    std::fs::write(ctx.reports_dir.join("summary.md"), &summary)?;
    Ok(summary)
}

// ---------------------------------------------------------------------------
// Tests (mock factory)
// ---------------------------------------------------------------------------

#[cfg(test)]
pub struct MockFactory {
    pub batch: usize,
    pub ctx: usize,
    pub vocab: usize,
}

#[cfg(test)]
impl EngineFactory for MockFactory {
    fn create(&self, variant: &str) -> Result<Box<dyn StepEngine>> {
        use crate::coordinator::{test_manifest, MockEngine};
        // Per-variant floors mirroring Table 1's ordering so report code
        // paths (best-model bolding etc.) are exercised realistically.
        let floor = match variant {
            "hybrid_mh_06" => 1.6889,
            "hybrid_06" => 1.6948,
            "gpt" => 1.7048,
            "hsm_ab" => 1.8625,
            "hsm_ab_mh" => 1.9767,
            _ => 1.88,
        };
        Ok(Box::new(MockEngine::new(
            test_manifest(variant, self.batch, self.ctx, self.vocab),
            floor,
            0.05,
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ExperimentCtx {
        let mut c = ExperimentCtx::new("ci");
        c.reports_dir = std::env::temp_dir().join("hsm_reports_test");
        c.corpus_bytes = 60_000;
        c.epochs = 2;
        c.eval_batches = Some(2);
        c
    }

    fn factory() -> MockFactory {
        MockFactory { batch: 4, ctx: 64, vocab: 512 }
    }

    #[test]
    fn table1_runs_and_bolds_best() {
        let md = run_table1(&factory(), &ctx(), &["hsm_ab", "gpt", "hybrid_mh_06"]).unwrap();
        assert!(md.contains("GPT") || md.contains("gpt"));
        assert!(md.contains("**"), "best loss should be bolded:\n{md}");
        // hybrid_mh_06 has the lowest floor — it must carry the bold.
        let bold_line = md.lines().find(|l| l.contains("**")).unwrap();
        assert!(bold_line.contains("hybrid_mh_06"), "{md}");
    }

    #[test]
    fn table2_emits_per_layer_taps() {
        let md = run_table2(&factory(), &ctx()).unwrap();
        assert!(md.contains("Layer 0"));
        assert!(md.lines().count() >= 4, "{md}");
    }

    #[test]
    fn fig7_and_fig8_emit_csv() {
        let c = ctx();
        let p7 = run_fig7(&factory(), &c, &["gpt", "hsm_ab"]).unwrap();
        assert!(p7.exists());
        let (p8, r) = run_fig8(&factory(), &c, &["gpt", "hsm_ab"]).unwrap();
        assert!(p8.exists());
        assert!(r < -0.9, "loss and accuracy must anti-correlate, got {r}");
    }

    #[test]
    fn table3_generates_for_all_prompts() {
        let md = run_table3(&factory(), &ctx(), &["hsm_ab"], 4).unwrap();
        // 11 prompt rows + 2 header lines.
        assert_eq!(md.lines().count(), 13, "{md}");
    }

    #[test]
    fn run_all_emits_everything_in_one_pass() {
        let c = {
            let mut c = ctx();
            c.reports_dir = std::env::temp_dir().join("hsm_reports_all");
            c
        };
        let md = run_all(&factory(), &c, &["hsm_ab", "gpt"], 3).unwrap();
        assert!(md.contains("## Table 1"));
        assert!(md.contains("## Table 2"));
        assert!(md.contains("## Table 3"));
        assert!(md.contains("pearson"));
        for f in ["table1.md", "table2.md", "table3.md", "fig7.csv", "fig8.csv", "summary.md"] {
            assert!(c.reports_dir.join(f).exists(), "{f} missing");
        }
    }
}
