//! Structured JSON-lines request-lifecycle log.
//!
//! One [`RequestEvent`] per line, in arrival order: `admitted` (left
//! the queue) → `started` (prefill done, decode loop entered) →
//! `first_token` → `finished`. Cancelled / timed-out / rejected
//! requests end with a `finished` event whose `finish` label says why
//! — the same labels [`crate::serve::FinishReason`] exposes over the
//! API.
//!
//! The sink is any `Write + Send` behind a mutex; the hot path only
//! takes it when an event fires (a handful of times per request, never
//! per token). Enable from the CLI with `hsm serve --log-requests
//! PATH` or programmatically via `ServeCfg::obs.request_log`.

use std::io::Write;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Value};

/// A single request-lifecycle event. Serialized as one JSON object
/// per line; `event` discriminates the variant.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestEvent {
    /// Request left the queue and was admitted to a decode session.
    Admitted { request_id: u64, prompt_tokens: u64, queue_wait_ms: f64 },
    /// Prefill finished (possibly partly served from the prefix
    /// cache) and the decode loop started.
    Started { request_id: u64, cached_prefix_len: u64, prefill_ms: f64 },
    /// First generated token emitted.
    FirstToken { request_id: u64, ttft_ms: f64 },
    /// Terminal event, for every finish reason (eot, max_tokens,
    /// ctx_full, timed_out, cancelled, rejected).
    Finished {
        request_id: u64,
        finish: String,
        tokens_generated: u64,
        e2e_ms: f64,
        /// Model variant label (the mixer-stack name, e.g. `hsm_ab`).
        mixer: String,
        /// Weight precision label (`f32` | `int8`).
        precision: String,
        /// Drafter label when speculation ran (e.g. `ngram:3`).
        drafter: Option<String>,
        /// Speculative verify rounds (0 without speculation).
        spec_rounds: u64,
        /// Draft tokens proposed / accepted.
        spec_drafted: u64,
        spec_accepted: u64,
        cached_prefix_len: u64,
    },
}

fn ms(v: f64) -> Value {
    // Microsecond resolution keeps lines compact and stable.
    json::num((v * 1000.0).round() / 1000.0)
}

impl RequestEvent {
    pub fn label(&self) -> &'static str {
        match self {
            RequestEvent::Admitted { .. } => "admitted",
            RequestEvent::Started { .. } => "started",
            RequestEvent::FirstToken { .. } => "first_token",
            RequestEvent::Finished { .. } => "finished",
        }
    }

    pub fn request_id(&self) -> u64 {
        match self {
            RequestEvent::Admitted { request_id, .. }
            | RequestEvent::Started { request_id, .. }
            | RequestEvent::FirstToken { request_id, .. }
            | RequestEvent::Finished { request_id, .. } => *request_id,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("event", json::s(self.label())),
            ("request_id", json::num(self.request_id() as f64)),
        ];
        match self {
            RequestEvent::Admitted { prompt_tokens, queue_wait_ms, .. } => {
                pairs.push(("prompt_tokens", json::num(*prompt_tokens as f64)));
                pairs.push(("queue_wait_ms", ms(*queue_wait_ms)));
            }
            RequestEvent::Started { cached_prefix_len, prefill_ms, .. } => {
                pairs.push(("cached_prefix_len", json::num(*cached_prefix_len as f64)));
                pairs.push(("prefill_ms", ms(*prefill_ms)));
            }
            RequestEvent::FirstToken { ttft_ms, .. } => {
                pairs.push(("ttft_ms", ms(*ttft_ms)));
            }
            RequestEvent::Finished {
                finish,
                tokens_generated,
                e2e_ms,
                mixer,
                precision,
                drafter,
                spec_rounds,
                spec_drafted,
                spec_accepted,
                cached_prefix_len,
                ..
            } => {
                pairs.push(("finish", json::s(finish)));
                pairs.push(("tokens_generated", json::num(*tokens_generated as f64)));
                pairs.push(("e2e_ms", ms(*e2e_ms)));
                pairs.push(("mixer", json::s(mixer)));
                pairs.push(("precision", json::s(precision)));
                if let Some(d) = drafter {
                    pairs.push(("drafter", json::s(d)));
                    pairs.push(("spec_rounds", json::num(*spec_rounds as f64)));
                    pairs.push(("spec_drafted", json::num(*spec_drafted as f64)));
                    pairs.push(("spec_accepted", json::num(*spec_accepted as f64)));
                }
                pairs.push(("cached_prefix_len", json::num(*cached_prefix_len as f64)));
            }
        }
        json::obj(pairs)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let event = v.get("event").as_str().ok_or_else(|| anyhow!("missing event field"))?;
        let id = |key: &str| -> Result<u64> {
            v.get(key).as_f64().map(|n| n as u64).ok_or_else(|| anyhow!("missing {key}"))
        };
        let msf = |key: &str| -> Result<f64> {
            v.get(key).as_f64().ok_or_else(|| anyhow!("missing {key}"))
        };
        let request_id = id("request_id")?;
        Ok(match event {
            "admitted" => RequestEvent::Admitted {
                request_id,
                prompt_tokens: id("prompt_tokens")?,
                queue_wait_ms: msf("queue_wait_ms")?,
            },
            "started" => RequestEvent::Started {
                request_id,
                cached_prefix_len: id("cached_prefix_len")?,
                prefill_ms: msf("prefill_ms")?,
            },
            "first_token" => {
                RequestEvent::FirstToken { request_id, ttft_ms: msf("ttft_ms")? }
            }
            "finished" => {
                let drafter = v.get("drafter").as_str().map(str::to_string);
                let spec = drafter.is_some();
                RequestEvent::Finished {
                    request_id,
                    finish: v
                        .get("finish")
                        .as_str()
                        .ok_or_else(|| anyhow!("missing finish"))?
                        .to_string(),
                    tokens_generated: id("tokens_generated")?,
                    e2e_ms: msf("e2e_ms")?,
                    mixer: v
                        .get("mixer")
                        .as_str()
                        .ok_or_else(|| anyhow!("missing mixer"))?
                        .to_string(),
                    precision: v
                        .get("precision")
                        .as_str()
                        .ok_or_else(|| anyhow!("missing precision"))?
                        .to_string(),
                    drafter,
                    spec_rounds: if spec { id("spec_rounds")? } else { 0 },
                    spec_drafted: if spec { id("spec_drafted")? } else { 0 },
                    spec_accepted: if spec { id("spec_accepted")? } else { 0 },
                    cached_prefix_len: id("cached_prefix_len")?,
                }
            }
            other => return Err(anyhow!("unknown request-log event {other:?}")),
        })
    }
}

/// A JSON-lines sink for [`RequestEvent`]s. Thread-safe; write errors
/// are counted but never surfaced to the serving path (telemetry must
/// not fail a request).
pub struct RequestLog {
    sink: Mutex<Box<dyn Write + Send>>,
    errors: std::sync::atomic::AtomicU64,
}

impl RequestLog {
    /// Log to a file (created/truncated), line-buffered per event.
    pub fn to_file(path: &Path) -> Result<Arc<Self>> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating request log {}", path.display()))?;
        Ok(Self::to_writer(Box::new(std::io::BufWriter::new(f))))
    }

    /// Log to any writer (tests inject a shared buffer here).
    pub fn to_writer(w: Box<dyn Write + Send>) -> Arc<Self> {
        Arc::new(RequestLog { sink: Mutex::new(w), errors: std::sync::atomic::AtomicU64::new(0) })
    }

    /// Append one event as a JSON line and flush it.
    pub fn log(&self, ev: &RequestEvent) {
        let line = ev.to_json().to_string();
        let mut sink = match self.sink.lock() {
            Ok(s) => s,
            Err(p) => p.into_inner(),
        };
        let ok = writeln!(sink, "{line}").and_then(|_| sink.flush());
        if ok.is_err() {
            self.errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    }

    /// Number of events dropped on write errors.
    pub fn write_errors(&self) -> u64 {
        self.errors.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_round_trip_through_json() {
        let events = vec![
            RequestEvent::Admitted { request_id: 7, prompt_tokens: 12, queue_wait_ms: 1.25 },
            RequestEvent::Started { request_id: 7, cached_prefix_len: 8, prefill_ms: 3.5 },
            RequestEvent::FirstToken { request_id: 7, ttft_ms: 4.75 },
            RequestEvent::Finished {
                request_id: 7,
                finish: "eot".into(),
                tokens_generated: 42,
                e2e_ms: 100.5,
                mixer: "hsm_ab".into(),
                precision: "f32".into(),
                drafter: Some("ngram:3".into()),
                spec_rounds: 9,
                spec_drafted: 36,
                spec_accepted: 30,
                cached_prefix_len: 8,
            },
        ];
        for ev in events {
            let text = ev.to_json().to_string();
            let back = RequestEvent::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, ev);
        }
    }

    #[test]
    fn finished_without_drafter_omits_spec_fields() {
        let ev = RequestEvent::Finished {
            request_id: 1,
            finish: "max_tokens".into(),
            tokens_generated: 5,
            e2e_ms: 2.0,
            mixer: "gpt".into(),
            precision: "int8".into(),
            drafter: None,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            cached_prefix_len: 0,
        };
        let text = ev.to_json().to_string();
        assert!(!text.contains("spec_rounds"));
        let back = RequestEvent::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn log_writes_one_line_per_event() {
        use std::sync::{Arc as A, Mutex as M};
        #[derive(Clone)]
        struct Buf(A<M<Vec<u8>>>);
        impl Write for Buf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let buf = Buf(A::new(M::new(Vec::new())));
        let log = RequestLog::to_writer(Box::new(buf.clone()));
        log.log(&RequestEvent::FirstToken { request_id: 3, ttft_ms: 1.0 });
        log.log(&RequestEvent::FirstToken { request_id: 4, ttft_ms: 2.0 });
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            json::parse(line).unwrap();
        }
        assert_eq!(log.write_errors(), 0);
    }
}
