//! `obs` — serving telemetry: metrics registry, latency histograms,
//! per-stage step timing, and structured request logs.
//!
//! The subsystem has three layers:
//!
//! * [`MetricsRegistry`] — a lock-free registry of atomic counters,
//!   gauges, and log-bucketed latency [`Histogram`]s (see [`hist`])
//!   covering the whole request lifecycle: queue wait, TTFT,
//!   per-token decode latency, end-to-end latency, speculative
//!   verify-round latency, request/token/prefix-cache/speculation
//!   counters, and per-stage step timing (prefill vs step vs fused
//!   verify; mixer vs FFN vs logits, keyed by mixer kind and weight
//!   precision). [`MetricsRegistry::render_prometheus`] serializes it
//!   all in Prometheus text format for the HTTP server's
//!   `GET /metrics` route; `GET /healthz` reads the same cells.
//! * [`RequestLog`] (see [`reqlog`]) — a JSON-lines
//!   request-lifecycle log (`admitted` → `started` → `first_token` →
//!   `finished`).
//! * [`ObsCfg`] / [`ObsRuntime`] — configuration on
//!   `ServeCfg::obs` and the resolved runtime handle the schedulers
//!   thread through the serving stack.
//!
//! Everything is hand-rolled on `std` — no Prometheus client crate,
//! no logging framework. The recording side is gated so the
//! zero-allocation decode hot path stays allocation-free: counters
//! are single relaxed `fetch_add`s, histogram recording is lock-free
//! sharded, per-stage timing only reads the clock on sampled steps
//! (every [`ObsCfg::stage_sample_every`]th), and with telemetry off
//! the schedulers skip the hooks entirely.

pub mod hist;
pub mod reqlog;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::Manifest;
use crate::infer::SpecStats;

pub use hist::{HistSnapshot, Histogram};
pub use reqlog::{RequestEvent, RequestLog};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Telemetry configuration, carried on `ServeCfg::obs`.
#[derive(Clone)]
pub struct ObsCfg {
    /// Registry to record into; `None` gives the scheduler a private
    /// one (reachable via its `metrics()` accessor). Share one
    /// `Arc` to aggregate several schedulers into one scrape.
    pub metrics: Option<Arc<MetricsRegistry>>,
    /// Request/token/cache/speculation counters (single relaxed
    /// atomic adds).
    pub counters: bool,
    /// Latency histograms: queue wait, TTFT, per-token, end-to-end,
    /// verify rounds.
    pub timing: bool,
    /// Sample per-stage step timing (mixer/FFN/logits split) on every
    /// Nth step per session; `0` disables stage timing entirely.
    /// Sampling keeps the clock reads off most steps.
    pub stage_sample_every: usize,
    /// JSON-lines request-lifecycle log sink (see [`RequestLog`]).
    pub request_log: Option<Arc<RequestLog>>,
}

impl Default for ObsCfg {
    fn default() -> Self {
        ObsCfg {
            metrics: None,
            counters: true,
            timing: true,
            stage_sample_every: 16,
            request_log: None,
        }
    }
}

impl std::fmt::Debug for ObsCfg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsCfg")
            .field("metrics", &self.metrics.is_some())
            .field("counters", &self.counters)
            .field("timing", &self.timing)
            .field("stage_sample_every", &self.stage_sample_every)
            .field("request_log", &self.request_log.is_some())
            .finish()
    }
}

impl ObsCfg {
    /// Telemetry fully disabled: no counters, no histograms, no
    /// stage sampling, no log. The schedulers skip every hook.
    pub fn off() -> Self {
        ObsCfg {
            metrics: None,
            counters: false,
            timing: false,
            stage_sample_every: 0,
            request_log: None,
        }
    }

    /// True when no telemetry would be recorded at all.
    pub fn is_off(&self) -> bool {
        !self.counters
            && !self.timing
            && self.stage_sample_every == 0
            && self.request_log.is_none()
            && self.metrics.is_none()
    }
}

/// The resolved telemetry handle the schedulers thread through the
/// serving stack. Built once per scheduler from [`ObsCfg`].
pub struct ObsRuntime {
    pub registry: Arc<MetricsRegistry>,
    pub counters: bool,
    pub timing: bool,
    pub stage_sample_every: usize,
    pub log: Option<Arc<RequestLog>>,
}

impl ObsRuntime {
    /// Resolve a config; `None` when telemetry is fully off (callers
    /// then skip the hooks entirely).
    pub fn from_cfg(cfg: &ObsCfg) -> Option<Arc<ObsRuntime>> {
        if cfg.is_off() {
            return None;
        }
        Some(Arc::new(ObsRuntime {
            registry: cfg.metrics.clone().unwrap_or_default(),
            counters: cfg.counters,
            timing: cfg.timing,
            stage_sample_every: cfg.stage_sample_every,
            log: cfg.request_log.clone(),
        }))
    }

    /// Read the clock only when latency histograms are on.
    #[inline]
    pub fn now(&self) -> Option<Instant> {
        if self.timing {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Emit a request-log event (no-op without a sink).
    #[inline]
    pub fn emit(&self, ev: RequestEvent) {
        if let Some(log) = &self.log {
            log.log(&ev);
        }
    }
}

// ---------------------------------------------------------------------------
// Counter groups
// ---------------------------------------------------------------------------

/// Prefix-cache event counters plus a resident-entry gauge. The
/// `PrefixCache` holds one of these (its own by default, the
/// registry's when a scheduler wires the cache in), so `/healthz` and
/// `/metrics` read the very same cells.
#[derive(Default)]
pub struct CacheCounters {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub insertions: AtomicU64,
    pub evictions: AtomicU64,
    /// Snapshots currently resident (gauge).
    pub entries: AtomicU64,
    /// Approximate heap bytes of all resident snapshots (gauge) —
    /// compacted int8-image entries report roughly a quarter of their
    /// f32 size.
    pub resident_bytes: AtomicU64,
    /// Resident snapshots stored compacted at a quantized serving
    /// precision (gauge; `entries - quantized_entries` are f32).
    pub quantized_entries: AtomicU64,
}

impl CacheCounters {
    #[inline]
    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub fn inserted(&self, bytes: u64, quantized: bool) {
        self.insertions.fetch_add(1, Ordering::Relaxed);
        self.entries.fetch_add(1, Ordering::Relaxed);
        self.resident_bytes.fetch_add(bytes, Ordering::Relaxed);
        if quantized {
            self.quantized_entries.fetch_add(1, Ordering::Relaxed);
        }
    }
    #[inline]
    pub fn evicted(&self, bytes: u64, quantized: bool) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
        self.entries.fetch_sub(1, Ordering::Relaxed);
        self.resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
        if quantized {
            self.quantized_entries.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Aggregate speculative-decoding counters — the registry-backed
/// successor of the old `SpecCounters`: per-request `SpecStats` are
/// added here as requests finish, and `/healthz` + `/metrics` read
/// the same cells.
#[derive(Default)]
pub struct SpecCounterGroup {
    rounds: AtomicU64,
    drafted: AtomicU64,
    accepted: AtomicU64,
    emitted: AtomicU64,
    fused_passes: AtomicU64,
    fused_rows: AtomicU64,
}

impl SpecCounterGroup {
    pub fn add(&self, s: &SpecStats) {
        self.rounds.fetch_add(s.rounds, Ordering::Relaxed);
        self.drafted.fetch_add(s.drafted, Ordering::Relaxed);
        self.accepted.fetch_add(s.accepted, Ordering::Relaxed);
        self.emitted.fetch_add(s.emitted, Ordering::Relaxed);
        self.fused_passes.fetch_add(s.fused_passes, Ordering::Relaxed);
        self.fused_rows.fetch_add(s.fused_rows, Ordering::Relaxed);
    }

    /// Point-in-time aggregate across all finished requests.
    pub fn snapshot(&self) -> SpecStats {
        SpecStats {
            rounds: self.rounds.load(Ordering::Relaxed),
            drafted: self.drafted.load(Ordering::Relaxed),
            accepted: self.accepted.load(Ordering::Relaxed),
            emitted: self.emitted.load(Ordering::Relaxed),
            fused_passes: self.fused_passes.load(Ordering::Relaxed),
            fused_rows: self.fused_rows.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------------
// Per-stage step timing
// ---------------------------------------------------------------------------

/// Which step path a stage sample came from.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Phase {
    /// Prompt ingestion (`step` without logits / prefill loops).
    Prefill,
    /// The plain one-token decode step.
    Step,
    /// The fused multi-row speculative verify pass (`step_batch`).
    VerifyFused,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Step => "step",
            Phase::VerifyFused => "verify_fused",
        }
    }
}

/// One labeled per-stage timing series.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct StageKey {
    pub phase: &'static str,
    /// `mixer` | `ffn` | `logits`.
    pub stage: &'static str,
    /// Mixer kind of the layer (`-` for the shared logits stage).
    pub mixer: String,
    /// Weight precision label (`f32` | `int8`).
    pub precision: String,
}

/// Accumulated sampled wall time for one [`StageKey`].
#[derive(Default)]
pub struct StageCell {
    pub ns: AtomicU64,
    pub samples: AtomicU64,
}

impl StageCell {
    #[inline]
    pub fn record(&self, ns: u64) {
        self.ns.fetch_add(ns, Ordering::Relaxed);
        self.samples.fetch_add(1, Ordering::Relaxed);
    }
}

/// The per-phase stage cells a decode session records into,
/// pre-resolved so the sampled path touches only `Arc`'d atomics.
pub struct PhaseCells {
    /// One cell per layer (layers of the same mixer kind share one).
    pub mixer: Vec<Arc<StageCell>>,
    pub ffn: Vec<Arc<StageCell>>,
    pub logits: Arc<StageCell>,
}

/// Stage-timing handle attached to a `DecodeSession`. Holds resolved
/// registry cells for every (phase, stage, layer) combination plus
/// the sampling countdown; the engine's step paths call
/// [`StageObs::tick`] and, on sampled steps, time each stage into
/// [`PhaseCells`].
pub struct StageObs {
    sample_every: u64,
    countdown: u64,
    prefill: PhaseCells,
    step: PhaseCells,
    verify: PhaseCells,
}

impl StageObs {
    /// Resolve cells for a model (one per layer/stage/phase) against
    /// `registry`. `sample_every` must be > 0.
    pub fn attach(
        registry: &MetricsRegistry,
        manifest: &Manifest,
        precision: &str,
        sample_every: usize,
    ) -> Box<StageObs> {
        let cells = |phase: Phase| {
            let p = phase.label();
            PhaseCells {
                mixer: manifest
                    .layers
                    .iter()
                    .map(|l| {
                        registry.stage_cell(StageKey {
                            phase: p,
                            stage: "mixer",
                            mixer: l.kind.clone(),
                            precision: precision.to_string(),
                        })
                    })
                    .collect(),
                ffn: manifest
                    .layers
                    .iter()
                    .map(|l| {
                        registry.stage_cell(StageKey {
                            phase: p,
                            stage: "ffn",
                            mixer: l.kind.clone(),
                            precision: precision.to_string(),
                        })
                    })
                    .collect(),
                logits: registry.stage_cell(StageKey {
                    phase: p,
                    stage: "logits",
                    mixer: "-".to_string(),
                    precision: precision.to_string(),
                }),
            }
        };
        Box::new(StageObs {
            sample_every: sample_every.max(1) as u64,
            countdown: 0,
            prefill: cells(Phase::Prefill),
            step: cells(Phase::Step),
            verify: cells(Phase::VerifyFused),
        })
    }

    /// Advance the sampling countdown; true when this step should be
    /// timed (every `sample_every`th call, starting with the first).
    #[inline]
    pub fn tick(&mut self) -> bool {
        if self.countdown == 0 {
            self.countdown = self.sample_every - 1;
            true
        } else {
            self.countdown -= 1;
            false
        }
    }

    pub fn cells(&self, phase: Phase) -> &PhaseCells {
        match phase {
            Phase::Prefill => &self.prefill,
            Phase::Step => &self.step,
            Phase::VerifyFused => &self.verify,
        }
    }
}

// ---------------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------------

/// Finish-reason labels, in render order — one per
/// `serve::FinishReason` variant, mirroring
/// `serve::FinishReason::label`.  Public so the serve-side
/// exhaustiveness test can pin that every variant has exactly one
/// entry here (the registry would otherwise miscount a drifted label).
pub const FINISH_LABELS: [&str; 7] =
    ["eot", "max_tokens", "ctx_full", "timed_out", "cancelled", "rejected", "throttled"];

/// Lock-free registry of every serving metric. All recording methods
/// are single relaxed atomic operations (histograms: one shard
/// bucket add); the only lock is the stage-cell registration map,
/// taken once per session attach, never per step.
#[derive(Default)]
pub struct MetricsRegistry {
    // Latency histograms (u64 nanoseconds).
    pub queue_wait: Histogram,
    pub ttft: Histogram,
    pub token_latency: Histogram,
    pub e2e: Histogram,
    pub verify_round: Histogram,
    // Request/token counters.
    admitted: AtomicU64,
    /// One cell per [`FINISH_LABELS`] entry, plus a final `unknown`
    /// cell so a label outside the table lands somewhere visible
    /// instead of corrupting the first family's count.
    finished: [AtomicU64; FINISH_LABELS.len() + 1],
    tokens_generated: AtomicU64,
    prompt_tokens: AtomicU64,
    // Admission-control counters (SLO backpressure + quotas).
    throttled_queue_full: AtomicU64,
    throttled_quota: AtomicU64,
    queue_depth: AtomicU64,
    quota_tokens: AtomicU64,
    // Shared counter groups.
    pub spec: SpecCounterGroup,
    cache: OnceCacheCounters,
    /// Resident model-weight bytes by precision label, set once at
    /// scheduler construction (`"-"`/0 until a model is registered).
    model_resident: Mutex<(String, u64)>,
    // Per-stage timing cells, registered on session attach.
    stages: Mutex<BTreeMap<StageKey, Arc<StageCell>>>,
}

/// Lazily-shared cache counters (`Default` for `Arc` would give each
/// registry clone path its own).
struct OnceCacheCounters(Arc<CacheCounters>);

impl Default for OnceCacheCounters {
    fn default() -> Self {
        OnceCacheCounters(Arc::new(CacheCounters::default()))
    }
}

impl MetricsRegistry {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    // -- recording ----------------------------------------------------------

    #[inline]
    pub fn record_queue_wait(&self, d: Duration) {
        self.queue_wait.record(d.as_nanos() as u64);
    }
    #[inline]
    pub fn record_ttft(&self, d: Duration) {
        self.ttft.record(d.as_nanos() as u64);
    }
    #[inline]
    pub fn record_token_latency(&self, d: Duration) {
        self.token_latency.record(d.as_nanos() as u64);
    }
    #[inline]
    pub fn record_e2e(&self, d: Duration) {
        self.e2e.record(d.as_nanos() as u64);
    }
    #[inline]
    pub fn record_verify_round(&self, d: Duration) {
        self.verify_round.record(d.as_nanos() as u64);
    }

    #[inline]
    pub fn inc_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a finished request under its finish-reason label (one of
    /// `serve::FinishReason::label`'s values).  The mapping is total:
    /// a label outside [`FINISH_LABELS`] counts under the dedicated
    /// `unknown` cell (and fails a debug assertion) rather than
    /// silently inflating the first family.
    #[inline]
    pub fn inc_finished(&self, label: &str) {
        let ix = FINISH_LABELS.iter().position(|l| *l == label).unwrap_or_else(|| {
            debug_assert!(false, "unknown finish label {label:?} — update obs::FINISH_LABELS");
            FINISH_LABELS.len()
        });
        self.finished[ix].fetch_add(1, Ordering::Relaxed);
    }

    /// Count a request refused by admission control, by cause
    /// (`serve::AdmissionError::cause`: `"queue_full"` or `"quota"`).
    #[inline]
    pub fn inc_throttled(&self, cause: &str) {
        let c = match cause {
            "quota" => &self.throttled_quota,
            _ => &self.throttled_queue_full,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the pending-queue depth observed after a scheduling or
    /// admission pass (`hsm_queue_depth` gauge).
    #[inline]
    pub fn set_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Add tokens charged against a per-user quota window (prompt +
    /// generation budget, charged at admission).
    #[inline]
    pub fn add_quota_tokens(&self, n: u64) {
        self.quota_tokens.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_tokens_generated(&self, n: u64) {
        self.tokens_generated.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_prompt_tokens(&self, n: u64) {
        self.prompt_tokens.fetch_add(n, Ordering::Relaxed);
    }

    // -- views --------------------------------------------------------------

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn finished_total(&self) -> u64 {
        self.finished.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Requests refused by admission control (queue depth + quotas).
    pub fn throttled_total(&self) -> u64 {
        self.throttled_queue_full.load(Ordering::Relaxed)
            + self.throttled_quota.load(Ordering::Relaxed)
    }

    /// Pending-queue depth at the last scheduling/admission pass.
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Tokens charged against per-user quota windows.
    pub fn quota_tokens_charged(&self) -> u64 {
        self.quota_tokens.load(Ordering::Relaxed)
    }

    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated.load(Ordering::Relaxed)
    }

    /// The cache-counter cells; schedulers hand these to their
    /// `PrefixCache` so `/metrics` and `cache.stats()` agree.
    pub fn cache_counters(&self) -> Arc<CacheCounters> {
        Arc::clone(&self.cache.0)
    }

    /// Register the served model's resident weight footprint (bytes at
    /// its serving precision — `Model::resident_weight_bytes`), shown
    /// as the `hsm_model_resident_weight_bytes{precision=...}` gauge.
    /// Schedulers call this once at construction.
    pub fn set_model_resident(&self, precision: &str, bytes: u64) {
        let mut g = match self.model_resident.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        *g = (precision.to_string(), bytes);
    }

    /// The registered (precision label, resident weight bytes), or
    /// `("-", 0)` before any model registered.
    pub fn model_resident(&self) -> (String, u64) {
        let g = match self.model_resident.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if g.0.is_empty() {
            ("-".to_string(), 0)
        } else {
            g.clone()
        }
    }

    /// Resolve (or register) the cell for one stage-timing key.
    pub fn stage_cell(&self, key: StageKey) -> Arc<StageCell> {
        let mut map = match self.stages.lock() {
            Ok(m) => m,
            Err(p) => p.into_inner(),
        };
        Arc::clone(map.entry(key).or_default())
    }

    /// Snapshot of every registered stage cell.
    pub fn stage_snapshot(&self) -> Vec<(StageKey, u64, u64)> {
        let map = match self.stages.lock() {
            Ok(m) => m,
            Err(p) => p.into_inner(),
        };
        map.iter()
            .map(|(k, c)| {
                (k.clone(), c.ns.load(Ordering::Relaxed), c.samples.load(Ordering::Relaxed))
            })
            .collect()
    }

    // -- exposition ---------------------------------------------------------

    /// Serialize the whole registry in Prometheus text exposition
    /// format (`text/plain; version=0.0.4`). Every family is always
    /// present (zero-valued when untouched) so scrapers see a stable
    /// schema; histogram `le` series elide empty buckets.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);

        let hists: [(&str, &str, &Histogram); 5] = [
            ("hsm_queue_wait_seconds", "Queue wait before admission.", &self.queue_wait),
            ("hsm_ttft_seconds", "Time from submit to first generated token.", &self.ttft),
            (
                "hsm_token_latency_seconds",
                "Gap between consecutive generated tokens.",
                &self.token_latency,
            ),
            ("hsm_request_seconds", "End-to-end request latency.", &self.e2e),
            (
                "hsm_spec_verify_round_seconds",
                "Speculative verify-round latency (draft + score + accept).",
                &self.verify_round,
            ),
        ];
        for (name, help, h) in hists {
            render_histogram(&mut out, name, help, &h.snapshot());
        }

        render_counter(
            &mut out,
            "hsm_requests_admitted_total",
            "Requests admitted to a decode session.",
            self.admitted(),
        );
        let _ = writeln!(out, "# HELP hsm_requests_finished_total Requests finished, by reason.");
        let _ = writeln!(out, "# TYPE hsm_requests_finished_total counter");
        for (label, c) in FINISH_LABELS.iter().zip(self.finished.iter()) {
            let _ = writeln!(
                out,
                "hsm_requests_finished_total{{finish=\"{label}\"}} {}",
                c.load(Ordering::Relaxed)
            );
        }
        // The overflow cell renders only when something actually landed
        // in it (a drifted label) — the stable schema stays 1:1 with
        // FINISH_LABELS.
        let unknown = self.finished[FINISH_LABELS.len()].load(Ordering::Relaxed);
        if unknown > 0 {
            let _ =
                writeln!(out, "hsm_requests_finished_total{{finish=\"unknown\"}} {unknown}");
        }
        let _ = writeln!(
            out,
            "# HELP hsm_requests_throttled_total Requests refused by admission control, by cause."
        );
        let _ = writeln!(out, "# TYPE hsm_requests_throttled_total counter");
        for (cause, c) in
            [("queue_full", &self.throttled_queue_full), ("quota", &self.throttled_quota)]
        {
            let _ = writeln!(
                out,
                "hsm_requests_throttled_total{{cause=\"{cause}\"}} {}",
                c.load(Ordering::Relaxed)
            );
        }
        let _ = writeln!(
            out,
            "# HELP hsm_queue_depth Jobs waiting for admission at the last scheduling pass."
        );
        let _ = writeln!(out, "# TYPE hsm_queue_depth gauge");
        let _ = writeln!(out, "hsm_queue_depth {}", self.queue_depth.load(Ordering::Relaxed));
        render_counter(
            &mut out,
            "hsm_quota_tokens_charged_total",
            "Tokens (prompt + budget) charged against per-user quota windows at admission.",
            self.quota_tokens.load(Ordering::Relaxed),
        );
        render_counter(
            &mut out,
            "hsm_tokens_generated_total",
            "Tokens generated across all requests.",
            self.tokens_generated(),
        );
        render_counter(
            &mut out,
            "hsm_prompt_tokens_total",
            "Prompt tokens ingested (prefill, including cached prefixes).",
            self.prompt_tokens.load(Ordering::Relaxed),
        );

        let cache = &self.cache.0;
        let _ = writeln!(out, "# HELP hsm_prefix_cache_events_total Prefix-cache events.");
        let _ = writeln!(out, "# TYPE hsm_prefix_cache_events_total counter");
        for (ev, c) in [
            ("hit", &cache.hits),
            ("miss", &cache.misses),
            ("insertion", &cache.insertions),
            ("eviction", &cache.evictions),
        ] {
            let _ = writeln!(
                out,
                "hsm_prefix_cache_events_total{{event=\"{ev}\"}} {}",
                c.load(Ordering::Relaxed)
            );
        }
        let _ = writeln!(out, "# HELP hsm_prefix_cache_entries Prompt-head snapshots resident.");
        let _ = writeln!(out, "# TYPE hsm_prefix_cache_entries gauge");
        let _ =
            writeln!(out, "hsm_prefix_cache_entries {}", cache.entries.load(Ordering::Relaxed));
        let _ = writeln!(
            out,
            "# HELP hsm_prefix_cache_resident_bytes Approximate heap bytes of resident snapshots."
        );
        let _ = writeln!(out, "# TYPE hsm_prefix_cache_resident_bytes gauge");
        let _ = writeln!(
            out,
            "hsm_prefix_cache_resident_bytes {}",
            cache.resident_bytes.load(Ordering::Relaxed)
        );
        let _ = writeln!(
            out,
            "# HELP hsm_prefix_cache_quantized_entries Resident snapshots stored compacted at a \
             quantized precision."
        );
        let _ = writeln!(out, "# TYPE hsm_prefix_cache_quantized_entries gauge");
        let _ = writeln!(
            out,
            "hsm_prefix_cache_quantized_entries {}",
            cache.quantized_entries.load(Ordering::Relaxed)
        );

        let (precision, bytes) = self.model_resident();
        let _ = writeln!(
            out,
            "# HELP hsm_model_resident_weight_bytes Weight bytes resident at the serving \
             precision."
        );
        let _ = writeln!(out, "# TYPE hsm_model_resident_weight_bytes gauge");
        let _ = writeln!(
            out,
            "hsm_model_resident_weight_bytes{{precision=\"{}\"}} {bytes}",
            escape_label(&precision)
        );

        let spec = self.spec.snapshot();
        render_counter(
            &mut out,
            "hsm_spec_rounds_total",
            "Speculative verify rounds.",
            spec.rounds,
        );
        let _ = writeln!(out, "# HELP hsm_spec_tokens_total Speculative tokens, by outcome.");
        let _ = writeln!(out, "# TYPE hsm_spec_tokens_total counter");
        for (kind, v) in
            [("drafted", spec.drafted), ("accepted", spec.accepted), ("emitted", spec.emitted)]
        {
            let _ = writeln!(out, "hsm_spec_tokens_total{{kind=\"{kind}\"}} {v}");
        }
        render_counter(
            &mut out,
            "hsm_spec_fused_passes_total",
            "Verify rounds scored in one fused step_batch pass.",
            spec.fused_passes,
        );
        render_counter(
            &mut out,
            "hsm_spec_fused_rows_total",
            "Positions scored across all fused passes.",
            spec.fused_rows,
        );

        let stages = self.stage_snapshot();
        let _ = writeln!(
            out,
            "# HELP hsm_stage_seconds_total Sampled wall time per step stage, by phase, \
             stage, mixer kind and precision."
        );
        let _ = writeln!(out, "# TYPE hsm_stage_seconds_total counter");
        for (k, ns, _) in &stages {
            let _ = writeln!(
                out,
                "hsm_stage_seconds_total{{phase=\"{}\",stage=\"{}\",mixer=\"{}\",\
                 precision=\"{}\"}} {}",
                k.phase,
                k.stage,
                escape_label(&k.mixer),
                escape_label(&k.precision),
                fmt_secs(*ns)
            );
        }
        let _ = writeln!(out, "# HELP hsm_stage_samples_total Sampled steps per stage series.");
        let _ = writeln!(out, "# TYPE hsm_stage_samples_total counter");
        for (k, _, samples) in &stages {
            let _ = writeln!(
                out,
                "hsm_stage_samples_total{{phase=\"{}\",stage=\"{}\",mixer=\"{}\",\
                 precision=\"{}\"}} {samples}",
                k.phase,
                k.stage,
                escape_label(&k.mixer),
                escape_label(&k.precision),
            );
        }
        out
    }
}

fn fmt_secs(ns: u64) -> String {
    // Plain decimal (never scientific) keeps the output parseable by
    // the simplest scrapers; trim trailing zeros for compactness.
    let mut s = format!("{:.9}", ns as f64 / 1e9);
    while s.ends_with('0') {
        s.pop();
    }
    if s.ends_with('.') {
        s.push('0');
    }
    s
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn render_counter(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {value}");
}

fn render_histogram(out: &mut String, name: &str, help: &str, s: &HistSnapshot) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (hi_ns, cum) in s.cumulative_nonzero() {
        let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", fmt_secs(hi_ns));
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", s.count);
    let _ = writeln!(out, "{name}_sum {}", fmt_secs(s.sum));
    let _ = writeln!(out, "{name}_count {}", s.count);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_family_renders_when_untouched() {
        let r = MetricsRegistry::default();
        let text = r.render_prometheus();
        for family in [
            "hsm_queue_wait_seconds",
            "hsm_ttft_seconds",
            "hsm_token_latency_seconds",
            "hsm_request_seconds",
            "hsm_spec_verify_round_seconds",
            "hsm_requests_admitted_total",
            "hsm_requests_finished_total",
            "hsm_tokens_generated_total",
            "hsm_prompt_tokens_total",
            "hsm_prefix_cache_events_total",
            "hsm_prefix_cache_entries",
            "hsm_prefix_cache_resident_bytes",
            "hsm_prefix_cache_quantized_entries",
            "hsm_model_resident_weight_bytes",
            "hsm_spec_rounds_total",
            "hsm_spec_tokens_total",
            "hsm_spec_fused_passes_total",
            "hsm_spec_fused_rows_total",
            "hsm_stage_seconds_total",
            "hsm_stage_samples_total",
            "hsm_requests_throttled_total",
            "hsm_queue_depth",
            "hsm_quota_tokens_charged_total",
        ] {
            assert!(text.contains(&format!("# TYPE {family} ")), "missing family {family}");
        }
    }

    /// The PR-9 gauges: resident model weights render with their
    /// precision label (`-`/0 before registration), and cache
    /// byte/precision gauges track insert/evict symmetrically.
    #[test]
    fn resident_gauges_render_and_track() {
        let r = MetricsRegistry::default();
        let text = r.render_prometheus();
        assert!(text.contains("hsm_model_resident_weight_bytes{precision=\"-\"} 0"));
        r.set_model_resident("int4", 12345);
        let text = r.render_prometheus();
        assert!(text.contains("hsm_model_resident_weight_bytes{precision=\"int4\"} 12345"));

        let c = r.cache_counters();
        c.inserted(1000, true);
        c.inserted(400, false);
        let text = r.render_prometheus();
        assert!(text.contains("hsm_prefix_cache_resident_bytes 1400"));
        assert!(text.contains("hsm_prefix_cache_quantized_entries 1"));
        assert!(text.contains("hsm_prefix_cache_entries 2"));
        c.evicted(1000, true);
        let text = r.render_prometheus();
        assert!(text.contains("hsm_prefix_cache_resident_bytes 400"));
        assert!(text.contains("hsm_prefix_cache_quantized_entries 0"));
    }

    #[test]
    fn histogram_render_is_cumulative_and_consistent() {
        let r = MetricsRegistry::default();
        for ms in [1u64, 5, 5, 20, 100] {
            r.record_ttft(Duration::from_millis(ms));
        }
        let text = r.render_prometheus();
        let mut last = 0u64;
        let mut inf = None;
        for line in text.lines().filter(|l| l.starts_with("hsm_ttft_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket counts must be cumulative: {line}");
            last = v;
            if line.contains("+Inf") {
                inf = Some(v);
            }
        }
        assert_eq!(inf, Some(5));
        assert!(text.contains("hsm_ttft_seconds_count 5"));
    }

    #[test]
    fn finished_labels_cover_every_reason() {
        let r = MetricsRegistry::default();
        for l in FINISH_LABELS {
            r.inc_finished(l);
        }
        assert_eq!(r.finished_total(), FINISH_LABELS.len() as u64);
        let text = r.render_prometheus();
        for l in FINISH_LABELS {
            assert!(text.contains(&format!("finish=\"{l}\"}} 1")), "missing label {l}");
        }
        assert!(!text.contains("finish=\"unknown\""), "no drifted labels were recorded");
    }

    /// A label outside FINISH_LABELS must not inflate the first family
    /// (release builds): it lands in the dedicated overflow cell and
    /// renders as `finish="unknown"`.  (Debug builds catch the drift
    /// earlier with an assertion — exercised here only when
    /// debug_assertions are off.)
    #[test]
    #[cfg(not(debug_assertions))]
    fn unknown_finish_label_counts_as_unknown() {
        let r = MetricsRegistry::default();
        r.inc_finished("not-a-real-label");
        assert_eq!(r.finished_total(), 1);
        let text = r.render_prometheus();
        assert!(text.contains("finish=\"unknown\"} 1"));
        assert!(text.contains(&format!("finish=\"{}\"}} 0", FINISH_LABELS[0])));
    }

    /// The admission-control families: throttle causes count
    /// independently, the queue-depth gauge overwrites, and quota
    /// token charges accumulate.
    #[test]
    fn throttle_families_render_and_count() {
        let r = MetricsRegistry::default();
        let text = r.render_prometheus();
        assert!(text.contains("hsm_requests_throttled_total{cause=\"queue_full\"} 0"));
        assert!(text.contains("hsm_requests_throttled_total{cause=\"quota\"} 0"));
        assert!(text.contains("hsm_queue_depth 0"));
        assert!(text.contains("hsm_quota_tokens_charged_total 0"));
        r.inc_throttled("queue_full");
        r.inc_throttled("quota");
        r.inc_throttled("quota");
        r.set_queue_depth(7);
        r.set_queue_depth(3);
        r.add_quota_tokens(40);
        r.add_quota_tokens(2);
        assert_eq!(r.throttled_total(), 3);
        assert_eq!(r.queue_depth(), 3);
        assert_eq!(r.quota_tokens_charged(), 42);
        let text = r.render_prometheus();
        assert!(text.contains("hsm_requests_throttled_total{cause=\"queue_full\"} 1"));
        assert!(text.contains("hsm_requests_throttled_total{cause=\"quota\"} 2"));
        assert!(text.contains("hsm_queue_depth 3"));
        assert!(text.contains("hsm_quota_tokens_charged_total 42"));
    }

    #[test]
    fn stage_cells_are_shared_per_key() {
        let r = MetricsRegistry::default();
        let key = StageKey {
            phase: "step",
            stage: "mixer",
            mixer: "hsm".into(),
            precision: "f32".into(),
        };
        let a = r.stage_cell(key.clone());
        let b = r.stage_cell(key);
        a.record(100);
        b.record(50);
        let snap = r.stage_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1, 150);
        assert_eq!(snap[0].2, 2);
    }

    #[test]
    fn obs_runtime_resolves_off_to_none() {
        assert!(ObsRuntime::from_cfg(&ObsCfg::off()).is_none());
        let rt = ObsRuntime::from_cfg(&ObsCfg::default()).unwrap();
        assert!(rt.counters && rt.timing);
        assert!(rt.now().is_some());
    }
}
