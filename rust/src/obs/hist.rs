//! Lock-free log-bucketed latency histograms.
//!
//! The recording side is built for the serving hot path: a
//! [`Histogram`] is a fixed set of per-thread *shards*, each an array
//! of relaxed `AtomicU64` buckets, so concurrent workers never contend
//! on a lock and never allocate. Values are u64 nanoseconds.
//!
//! Bucketing is HdrHistogram-style: values below [`LINEAR_MAX`] get
//! exact unit-width buckets; above that, each power-of-two octave is
//! split into [`SUB_BUCKETS`] linear sub-buckets (4 significant
//! mantissa bits), bounding the relative error of any reported
//! quantile at `1/16 = 6.25%`. The full u64 range is covered — no
//! clamping, no overflow.
//!
//! Reading is snapshot-based: [`Histogram::snapshot`] sums the shards
//! into a plain [`HistSnapshot`], which supports exact rank arithmetic
//! ([`HistSnapshot::quantile_bounds`] returns the bucket *containing*
//! the true order statistic) and associative merging across histograms
//! (e.g. one per backend process).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Sub-bucket resolution: each octave is split into `2^SUB_BITS`
/// linear buckets.
pub const SUB_BITS: u32 = 4;
/// Sub-buckets per octave.
pub const SUB_BUCKETS: usize = 1 << SUB_BITS;
/// Values `< LINEAR_MAX` land in exact unit-width buckets.
pub const LINEAR_MAX: u64 = SUB_BUCKETS as u64;
/// Octaves above the linear region: top bit positions `SUB_BITS..64`.
const OCTAVES: usize = 64 - SUB_BITS as usize;
/// Total bucket count (`16 + 60*16 = 976` at `SUB_BITS = 4`).
pub const N_BUCKETS: usize = SUB_BUCKETS + OCTAVES * SUB_BUCKETS;

/// Recording shards; a small fixed pool keyed by thread.
const N_SHARDS: usize = 8;

/// Map a value to its bucket index. Monotonic in `v`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    // Top bit position h >= SUB_BITS; `v >> (h - SUB_BITS)` is in
    // [16, 32), so subtracting 16 yields the sub-bucket.
    let h = 63 - v.leading_zeros();
    let octave = (h - SUB_BITS) as usize;
    let sub = (v >> (h - SUB_BITS)) as usize - SUB_BUCKETS;
    SUB_BUCKETS + octave * SUB_BUCKETS + sub
}

/// Inclusive `[lo, hi]` value range of bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    debug_assert!(i < N_BUCKETS);
    if i < SUB_BUCKETS {
        return (i as u64, i as u64);
    }
    let j = i - SUB_BUCKETS;
    let octave = (j / SUB_BUCKETS) as u32;
    let sub = (j % SUB_BUCKETS) as u64;
    // hi = (base + 1) << octave - 1, written overflow-free so the last
    // bucket tops out at exactly u64::MAX.
    let lo = (LINEAR_MAX + sub) << octave;
    let hi = lo + ((1u64 << octave) - 1);
    (lo, hi)
}

thread_local! {
    /// Shard slot for this thread, assigned round-robin on first use.
    static SHARD: usize = {
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        NEXT.fetch_add(1, Ordering::Relaxed) % N_SHARDS
    };
}

struct Shard {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: Box<[AtomicU64]>,
}

impl Shard {
    fn new() -> Self {
        let buckets: Vec<AtomicU64> = (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Shard { count: AtomicU64::new(0), sum: AtomicU64::new(0), buckets: buckets.into() }
    }
}

/// A concurrent log-bucketed histogram of u64 values (nanoseconds by
/// convention). `record` is lock-free and allocation-free.
pub struct Histogram {
    shards: Box<[Shard]>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { shards: (0..N_SHARDS).map(|_| Shard::new()).collect() }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value into this thread's shard.
    #[inline]
    pub fn record(&self, v: u64) {
        let shard = &self.shards[SHARD.with(|s| *s)];
        shard.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        shard.count.fetch_add(1, Ordering::Relaxed);
        shard.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Merge every shard into a point-in-time snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut out = HistSnapshot::empty();
        for shard in self.shards.iter() {
            out.count += shard.count.load(Ordering::Relaxed);
            out.sum += shard.sum.load(Ordering::Relaxed);
            for (acc, b) in out.buckets.iter_mut().zip(shard.buckets.iter()) {
                *acc += b.load(Ordering::Relaxed);
            }
        }
        out
    }
}

/// A plain (non-atomic) copy of a histogram's state. Mergeable and
/// queryable; merging is associative and commutative.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct HistSnapshot {
    pub count: u64,
    /// Sum of all recorded values (nanoseconds by convention).
    pub sum: u64,
    buckets: Vec<u64>,
}

impl HistSnapshot {
    pub fn empty() -> Self {
        HistSnapshot { count: 0, sum: 0, buckets: vec![0; N_BUCKETS] }
    }

    /// Record into a snapshot directly (single-threaded use: tests,
    /// offline aggregation).
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Accumulate `other` into `self`.
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    /// The `[lo, hi]` bounds of the bucket holding the `q`-quantile
    /// (nearest-rank on the 0-based sorted order: rank
    /// `round(q * (count - 1))`). `None` when empty. The true order
    /// statistic is guaranteed to lie within the returned bounds.
    pub fn quantile_bounds(&self, q: f64) -> Option<(u64, u64)> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = (q * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Some(bucket_bounds(i));
            }
        }
        // Unreachable when count > 0; keep a defensive fallback.
        Some(bucket_bounds(N_BUCKETS - 1))
    }

    /// Upper bound of the `q`-quantile bucket, or 0 when empty. This
    /// is the value exposed as p50/p95/p99 (≤ 6.25% above the true
    /// order statistic).
    pub fn quantile(&self, q: f64) -> u64 {
        self.quantile_bounds(q).map(|(_, hi)| hi).unwrap_or(0)
    }

    /// Mean of recorded values, or 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Non-empty buckets as `(upper_bound, cumulative_count)` pairs,
    /// in increasing bucket order — the Prometheus `le` series minus
    /// its empty runs.
    pub fn cumulative_nonzero(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((bucket_bounds(i).1, cum));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotonic_and_in_bounds() {
        let probes = [
            0u64,
            1,
            15,
            16,
            17,
            31,
            32,
            33,
            1000,
            123_456_789,
            u64::MAX / 2,
            u64::MAX - 1,
            u64::MAX,
        ];
        let mut last = 0usize;
        for &v in &probes {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS, "bucket {i} out of range for {v}");
            assert!(i >= last, "bucket index not monotonic at {v}");
            last = i;
        }
    }

    #[test]
    fn bounds_contain_their_values() {
        let mut probe = 1u64;
        while probe < u64::MAX / 3 {
            for v in [probe.saturating_sub(1), probe, probe + 1] {
                let (lo, hi) = bucket_bounds(bucket_index(v));
                assert!(lo <= v && v <= hi, "value {v} outside bucket [{lo}, {hi}]");
            }
            probe = probe.saturating_mul(3) / 2 + 1;
        }
        let (_, hi) = bucket_bounds(bucket_index(u64::MAX));
        assert_eq!(hi, u64::MAX);
    }

    #[test]
    fn bounds_tile_the_u64_range() {
        // Every bucket starts exactly one past the previous bucket's end.
        let mut expect_lo = 0u64;
        for i in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expect_lo, "gap/overlap at bucket {i}");
            assert!(hi >= lo);
            if i + 1 < N_BUCKETS {
                expect_lo = hi + 1;
            } else {
                assert_eq!(hi, u64::MAX);
            }
        }
    }

    #[test]
    fn relative_error_is_bounded() {
        for i in SUB_BUCKETS..N_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            let width = (hi - lo) as f64;
            assert!(width / lo as f64 <= 1.0 / SUB_BUCKETS as f64 + 1e-12, "bucket {i} too wide");
        }
    }

    #[test]
    fn concurrent_records_all_land() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1_000_000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4000);
    }

    #[test]
    fn quantiles_bracket_exact_order_statistics() {
        let mut s = HistSnapshot::empty();
        let mut shadow: Vec<u64> = Vec::new();
        let mut x = 0x9e3779b97f4a7c15u64;
        for _ in 0..5000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = x % 50_000_000;
            s.record(v);
            shadow.push(v);
        }
        shadow.sort_unstable();
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            let rank = (q * (shadow.len() - 1) as f64).round() as usize;
            let exact = shadow[rank];
            let (lo, hi) = s.quantile_bounds(q).unwrap();
            assert!(lo <= exact && exact <= hi, "q={q}: exact {exact} outside [{lo}, {hi}]");
        }
    }
}
